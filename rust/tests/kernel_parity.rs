//! Differential kernel-parity suite — the lockdown for the SIMD
//! dispatch seam (DESIGN.md §Compute-plane).
//!
//! The `Simd` rung's portable level is the executable specification:
//! every vector level (AVX2, AVX-512 when built) must reproduce its
//! bits exactly, on every adversarial shape SIMD classically gets
//! wrong — d ∈ {0, 1, 3, 4, 5, 7, 8, 15, 16, 17, 63, 64, 65},
//! unaligned row offsets, near-duplicate rows (the d² ≈ 0 clamp),
//! denormals, and ±0.0 — for both Gauss and Laplace, dense and CSR,
//! full-matrix and streamed/tiled access.  The mixed-precision path
//! has a different contract: bit-stable across levels, ULP-bounded
//! (pinned here) against the f64-accumulate rung.
//!
//! Tests print the detected/selected rung so CI logs show what the
//! runner actually covered.

use liquid_svm::data::csr::CsrMatrix;
use liquid_svm::data::matrix::Matrix;
use liquid_svm::data::rng::Rng;
use liquid_svm::kernel::simd::{self, SimdLevel, SimdPlan};
use liquid_svm::kernel::{GramBackend, GramSource, KernelKind, SparseGram, StreamedGram};

/// The adversarial dimension set from the issue: empty, sub-lane,
/// exact-lane, lane±1, and the same around 16 and 64.
const DIMS: &[usize] = &[0, 1, 3, 4, 5, 7, 8, 15, 16, 17, 63, 64, 65];

fn print_rungs(ctx: &str) {
    let levels: Vec<&str> = simd::available().iter().map(|l| l.name()).collect();
    println!(
        "[{ctx}] detected={} available={}",
        simd::detect().name(),
        levels.join(",")
    );
}

/// Random matrix salted with the special values the suite must cover:
/// exact ±0.0 entries and single/double-precision denormals, plus one
/// near-duplicate row pair with large norms (worst cancellation for
/// the norm trick).
fn adversarial_matrix(m: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; m * d];
    for (t, x) in v.iter_mut().enumerate() {
        *x = match t % 9 {
            0 => 0.0,
            1 => -0.0,
            2 => 1.0e-41,  // f32 denormal
            3 => -7.5e-42, // f32 denormal
            _ => rng.range(-3.0, 3.0),
        };
    }
    if m >= 2 {
        for k in 0..d {
            let val = 55.0 + (k as f32) * 0.125;
            v[k] = val;
            v[d + k] = val;
        }
        if d > 0 {
            v[d] += 1.0e-4;
        }
    }
    Matrix::from_vec(v, m, d)
}

fn rand_sparse(m: usize, d: usize, nnz_row: usize, seed: u64) -> CsrMatrix {
    let mut rng = Rng::new(seed);
    let mut dense = Matrix::zeros(m, d);
    for i in 0..m {
        for _ in 0..nnz_row.min(d) {
            let j = rng.below(d.max(1));
            dense.set(i, j, rng.range(-3.0, 3.0));
        }
    }
    CsrMatrix::from_dense(&dense)
}

fn assert_bits_eq(a: &Matrix, b: &Matrix, ctx: &str) {
    assert_eq!(a.rows(), b.rows(), "{ctx}: row count");
    assert_eq!(a.cols(), b.cols(), "{ctx}: col count");
    for (u, v) in a.as_slice().iter().zip(b.as_slice()) {
        assert_eq!(u.to_bits(), v.to_bits(), "{ctx}: {u} vs {v}");
    }
}

// --------------------------------------------------- dense bit parity

#[test]
fn dense_levels_bit_identical_on_adversarial_shapes() {
    print_rungs("dense");
    for &d in DIMS {
        let x = adversarial_matrix(7, d, 10 + d as u64);
        let y = adversarial_matrix(9, d, 900 + d as u64);
        let reference = GramBackend::Simd(SimdPlan { level: SimdLevel::Portable, mixed: false });
        let want = reference.sq_dists(&x, &y);
        // d² is a distance: never negative, on any rung
        assert!(want.as_slice().iter().all(|&v| v >= 0.0), "d={d}: negative d²");
        for level in simd::available() {
            let be = GramBackend::Simd(SimdPlan::forced(level, false));
            assert_bits_eq(&be.sq_dists(&x, &y), &want, &format!("d={d} level={}", level.name()));
            // the Gram matrices inherit bit-equality through the same
            // exp for both kernel families
            for kind in [KernelKind::Gauss, KernelKind::Laplace] {
                let g_ref = reference.gram(&x, &y, 0.9, kind);
                let g = be.gram(&x, &y, 0.9, kind);
                assert_bits_eq(&g, &g_ref, &format!("d={d} {kind:?} level={}", level.name()));
            }
        }
    }
}

#[test]
fn raw_dot_bit_identical_on_unaligned_offsets_and_every_len() {
    // raw function-table level: exhaustive lengths 0..=67 × byte
    // offsets 0..8 — SIMD loads must be offset-oblivious, and the tail
    // handling must match the portable spec at every length
    print_rungs("raw-dot");
    let mut rng = Rng::new(77);
    let buf_x: Vec<f32> = (0..512).map(|_| rng.range(-2.0, 2.0)).collect();
    let buf_y: Vec<f32> = (0..512).map(|_| rng.range(-2.0, 2.0)).collect();
    let portable = simd::kernels(SimdLevel::Portable);
    for level in simd::available() {
        let k = simd::kernels(level);
        for d in 0..=67usize {
            for off in 0..8usize {
                let x = &buf_x[off..off + d];
                let y = &buf_y[off..off + d];
                assert_eq!(
                    (k.dot)(x, y).to_bits(),
                    (portable.dot)(x, y).to_bits(),
                    "dot level={} d={d} off={off}",
                    level.name()
                );
                assert_eq!(
                    (k.dot_mp)(x, y).to_bits(),
                    (portable.dot_mp)(x, y).to_bits(),
                    "dot_mp level={} d={d} off={off}",
                    level.name()
                );
            }
        }
    }
}

// ---------------------------------------------------- CSR bit parity

#[test]
fn csr_levels_bit_identical_on_adversarial_shapes() {
    print_rungs("csr");
    for &d in DIMS {
        if d == 0 {
            continue; // CSR with zero columns has no stored entries
        }
        let x = rand_sparse(8, d, (d / 2).max(1), 30 + d as u64);
        let y = rand_sparse(6, d, (d / 3).max(1), 800 + d as u64);
        let reference = GramBackend::Simd(SimdPlan { level: SimdLevel::Portable, mixed: false });
        let want = reference.sq_dists_csr(&x, &y);
        assert!(want.as_slice().iter().all(|&v| v >= 0.0), "d={d}: negative sparse d²");
        for level in simd::available() {
            let be = GramBackend::Simd(SimdPlan::forced(level, false));
            let got = be.sq_dists_csr(&x, &y);
            assert_bits_eq(&got, &want, &format!("csr d={d} level={}", level.name()));
        }
    }
}

// ------------------------------------------- mixed-precision contract

#[test]
fn mixed_precision_within_pinned_ulp_bound() {
    print_rungs("mixed-precision");
    for &d in DIMS {
        let x = adversarial_matrix(6, d, 50 + d as u64);
        let y = adversarial_matrix(5, d, 500 + d as u64);
        let exact = GramBackend::Simd(SimdPlan { level: SimdLevel::Portable, mixed: false })
            .sq_dists(&x, &y);
        let xn = x.row_sq_norms();
        let yn = y.row_sq_norms();
        for level in simd::available() {
            let mp = GramBackend::Simd(SimdPlan::forced(level, true)).sq_dists(&x, &y);
            for i in 0..x.rows() {
                for j in 0..y.rows() {
                    // pinned bound: f32 8-lane summation error is at
                    // most (d/8 + 8) rounding steps over terms bounded
                    // by Σ|x_k·y_k|, doubled by the 2⟨x,y⟩ scaling and
                    // measured against the norm magnitudes
                    let scale: f64 = (xn[i] as f64)
                        + (yn[j] as f64)
                        + 2.0 * x
                            .row(i)
                            .iter()
                            .zip(y.row(j))
                            .map(|(a, b)| (*a as f64 * *b as f64).abs())
                            .sum::<f64>();
                    let steps = (d / 8 + 9) as f64;
                    let tol = scale * steps * (f32::EPSILON as f64) + 1e-30;
                    let err = (mp.get(i, j) as f64 - exact.get(i, j) as f64).abs();
                    assert!(
                        err <= tol,
                        "mp level={} d={d} ({i},{j}): err={err:e} tol={tol:e}",
                        level.name()
                    );
                    // and the clamp holds on the mixed path too
                    assert!(mp.get(i, j) >= 0.0);
                }
            }
        }
    }
}

// -------------------------------------------- clamp-at-source contract

#[test]
fn clamp_at_source_contract_on_near_duplicates() {
    // The rung contract audited here: `‖x‖² + ‖y‖² − 2⟨x,y⟩` is
    // clamped to zero AT THE SOURCE (inside the distance kernel, like
    // blocked's `sq_dist_norms`), not later at exponentiation.  With
    // near-duplicate large-norm rows the cancellation goes negative
    // routinely; every rung must emit d² ≥ 0 and Gauss values ≤ 1.
    print_rungs("clamp");
    let mut rng = Rng::new(11);
    let base: Vec<f32> = (0..24).map(|_| rng.range(50.0, 60.0)).collect();
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for r in 0..16 {
        let mut v = base.clone();
        v[r % 24] += 1e-4 * (r as f32);
        rows.push(v);
    }
    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    let x = Matrix::from_rows(&refs);
    let mut plans: Vec<SimdPlan> =
        simd::available().into_iter().map(|l| SimdPlan::forced(l, false)).collect();
    plans.extend(simd::available().into_iter().map(|l| SimdPlan::forced(l, true)));
    for p in plans {
        let be = GramBackend::Simd(p);
        let d2 = be.sq_dists(&x, &x);
        for &v in d2.as_slice() {
            assert!(v >= 0.0, "{be:?}: d² went negative: {v}");
            // clamped zeros must be exact +0.0 (sign bit clear), so
            // downstream exp(±0) and sqrt(±0) can't see a -0.0
            if v == 0.0 {
                assert_eq!(v.to_bits(), 0, "{be:?}: clamp produced -0.0");
            }
        }
        let k = be.gram(&x, &x, 0.7, KernelKind::Gauss);
        assert!(
            k.as_slice().iter().all(|&v| v <= 1.0),
            "{be:?}: Gauss kernel leaked above 1 — clamp not at source"
        );
        for i in 0..x.rows() {
            let diag = k.get(i, i);
            assert!((diag - 1.0).abs() < 1e-6, "{be:?}: diag {diag}");
        }
    }
}

// ------------------------------------- plane invariants under the rung

#[test]
fn streamed_and_tiled_access_bit_identical_under_simd() {
    // the Gram plane's load-bearing contract, re-proven for the new
    // rung: streamed rows, per-pair gathers, and predict tiles must
    // reproduce the full-matrix bits
    print_rungs("plane");
    let x = adversarial_matrix(14, 17, 3);
    let y = adversarial_matrix(11, 17, 4);
    let (xn, yn) = (x.row_sq_norms(), y.row_sq_norms());
    for level in simd::available() {
        let be = GramBackend::Simd(SimdPlan::forced(level, false));
        for kind in [KernelKind::Gauss, KernelKind::Laplace] {
            let dense = be.gram(&x, &y, 0.9, kind);
            let mut s = StreamedGram::new(&be, &x, &y, &xn, &yn, kind, 0.9);
            for i in 0..x.rows() {
                assert_eq!(s.row(i), dense.row(i), "streamed row {i} level={}", level.name());
                for j in 0..y.rows() {
                    assert_eq!(
                        s.get(i, j).to_bits(),
                        dense.get(i, j).to_bits(),
                        "streamed get({i},{j}) level={}",
                        level.name()
                    );
                }
            }
            let idx: Vec<usize> = (0..y.rows()).step_by(2).collect();
            let mut out = vec![0.0f32; idx.len()];
            let mut s2 = StreamedGram::new(&be, &x, &y, &xn, &yn, kind, 0.9);
            s2.gather(3, &idx, &mut out);
            for (o, &j) in out.iter().zip(&idx) {
                assert_eq!(o.to_bits(), dense.get(3, j).to_bits(), "gather level={}", level.name());
            }
        }
        // tile path (the predict plane's source)
        let full = be.sq_dists(&x, &y);
        let (r0, r1) = (2usize, 9usize);
        let mut tile = vec![0.0f32; (r1 - r0) * y.rows()];
        be.sq_dists_tile_into(&x, r0, r1, &y, &xn, &yn, &mut tile);
        for (t, i) in (r0..r1).enumerate() {
            assert_eq!(
                &tile[t * y.rows()..(t + 1) * y.rows()],
                full.row(i),
                "tile row {i} level={}",
                level.name()
            );
        }
    }
}

#[test]
fn sparse_streamed_access_bit_identical_under_simd() {
    print_rungs("sparse-plane");
    let x = rand_sparse(10, 33, 9, 21);
    let y = rand_sparse(12, 33, 7, 22);
    let (xn, yn) = (x.row_sq_norms(), y.row_sq_norms());
    for level in simd::available() {
        let be = GramBackend::Simd(SimdPlan::forced(level, false));
        let d2 = be.sq_dists_csr(&x, &y);
        let dense = {
            let mut g = d2.clone();
            for v in g.as_mut_slice() {
                *v = KernelKind::Gauss.of_sq_dist(*v, 1.1);
            }
            g
        };
        let mut s = SparseGram::new(&be, &x, &y, &xn, &yn, KernelKind::Gauss, 1.1);
        for i in 0..x.rows() {
            assert_eq!(s.row(i), dense.row(i), "sparse streamed row {i} level={}", level.name());
        }
        let mut s2 = SparseGram::new(&be, &x, &y, &xn, &yn, KernelKind::Gauss, 1.1);
        for i in 0..x.rows() {
            for j in 0..y.rows() {
                assert_eq!(
                    s2.get(i, j).to_bits(),
                    dense.get(i, j).to_bits(),
                    "sparse get({i},{j}) level={}",
                    level.name()
                );
            }
        }
    }
}

// -------------------------------------------- override order contract

#[test]
fn resolution_order_env_beats_cli_beats_autodetect() {
    // all env scenarios live in ONE test: tests run multi-threaded and
    // the process environment is shared, so the suite touches
    // LIQUIDSVM_SIMD only here (everything else pins plans directly)
    let saved = std::env::var("LIQUIDSVM_SIMD").ok();
    let detected = simd::detect();

    std::env::remove_var("LIQUIDSVM_SIMD");
    // no env, no CLI: auto-detect
    assert_eq!(SimdPlan::resolve(None, false).unwrap().level, detected);
    // CLI pins (clamped to the CPU/build)
    assert_eq!(
        SimdPlan::resolve(Some(SimdLevel::Portable), false).unwrap().level,
        SimdLevel::Portable
    );
    assert_eq!(
        SimdPlan::resolve(Some(SimdLevel::Avx512), false).unwrap().level,
        SimdLevel::Avx512.min(detected)
    );

    // env beats CLI
    std::env::set_var("LIQUIDSVM_SIMD", "scalar");
    assert_eq!(
        SimdPlan::resolve(Some(SimdLevel::Avx2), false).unwrap().level,
        SimdLevel::Portable
    );
    std::env::set_var("LIQUIDSVM_SIMD", "avx2");
    assert_eq!(
        SimdPlan::resolve(Some(SimdLevel::Portable), false).unwrap().level,
        SimdLevel::Avx2.min(detected)
    );
    // unknown env value is a hard error, empty means unset
    std::env::set_var("LIQUIDSVM_SIMD", "sse9");
    assert!(SimdPlan::resolve(None, false).is_err());
    std::env::set_var("LIQUIDSVM_SIMD", "");
    assert_eq!(SimdPlan::resolve(None, false).unwrap().level, detected);

    match saved {
        Some(v) => std::env::set_var("LIQUIDSVM_SIMD", v),
        None => std::env::remove_var("LIQUIDSVM_SIMD"),
    }
    println!("[resolution] {}", SimdPlan::forced(detected, false).describe());
}

// ------------------------------------- end-to-end dispatch invariance

#[test]
fn cv_selection_bit_identical_across_levels() {
    // in-process twin of the CLI roundtrip below, mirroring the
    // jobs-N≡jobs-1 property: the whole CV pipeline — folds, grid,
    // solver, selection — must pick the same (γ*, λ*) and produce
    // bit-identical fold coefficients on every level
    use liquid_svm::cv::{run_cv, CvConfig, Grid};
    use liquid_svm::data::synth;
    use liquid_svm::metrics::Loss;
    use liquid_svm::solver::SolverKind;
    print_rungs("cv");
    let n = 150;
    let data = synth::banana_binary(n, 9);
    let mut cfg = CvConfig::new(
        Grid::default_grid(0, n - n / 3, data.dim()),
        SolverKind::Hinge { w: 0.5 },
        Loss::Classification,
    );
    cfg.folds = 3;
    cfg.seed = 9;
    cfg.backend = GramBackend::Simd(SimdPlan { level: SimdLevel::Portable, mixed: false });
    let want = run_cv(&data, &cfg);
    for level in simd::available() {
        let mut c = cfg.clone();
        c.backend = GramBackend::Simd(SimdPlan::forced(level, false));
        let got = run_cv(&data, &c);
        assert_eq!(want.best_gamma.to_bits(), got.best_gamma.to_bits(), "level={}", level.name());
        assert_eq!(
            want.best_lambda.to_bits(),
            got.best_lambda.to_bits(),
            "level={}",
            level.name()
        );
        assert_eq!(want.points_evaluated, got.points_evaluated);
        for (a, b) in want.models.iter().zip(&got.models) {
            assert_eq!(
                a.coef.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.coef.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "fold coefficients differ on level {}",
                level.name()
            );
        }
    }
}

#[test]
fn e2e_train_predict_roundtrip_invariant_under_env_override() {
    // the full CLI surface: train --backend simd under a forced-scalar
    // env vs the auto-detected rung must write byte-identical model
    // files (spec, selected (γ*, λ*), coefficients) and byte-identical
    // prediction files through a persisted-model roundtrip
    use std::process::Command;
    fn bin() -> Command {
        Command::new(env!("CARGO_BIN_EXE_liquidsvm"))
    }
    println!("[e2e] {}", SimdPlan::resolve(None, false).unwrap().describe());
    let dir = std::env::temp_dir().join(format!("lsvm-simd-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let run = |tag: &str, env_val: Option<&str>| -> (Vec<u8>, Vec<u8>, String, String) {
        let sol = dir.join(format!("{tag}.sol"));
        let preds = dir.join(format!("{tag}.txt"));
        let mut c = bin();
        c.args([
            "train", "--data", "banana", "--scenario", "binary", "--n", "240", "--folds", "3",
            "--seed", "11", "--backend", "simd", "--save",
        ])
        .arg(&sol);
        match env_val {
            Some(v) => c.env("LIQUIDSVM_SIMD", v),
            None => c.env_remove("LIQUIDSVM_SIMD"),
        };
        let out = c.output().unwrap();
        assert!(out.status.success(), "train({tag}): {}", String::from_utf8_lossy(&out.stderr));
        let train_line = String::from_utf8_lossy(&out.stdout).into_owned();
        let mut c = bin();
        c.args([
            "predict", "--model",
        ])
        .arg(&sol)
        .args(["--data", "banana", "--n", "240", "--seed", "11", "--backend", "simd", "--out"])
        .arg(&preds);
        match env_val {
            Some(v) => c.env("LIQUIDSVM_SIMD", v),
            None => c.env_remove("LIQUIDSVM_SIMD"),
        };
        let out = c.output().unwrap();
        assert!(out.status.success(), "predict({tag}): {}", String::from_utf8_lossy(&out.stderr));
        let predict_line = String::from_utf8_lossy(&out.stdout).into_owned();
        (std::fs::read(&sol).unwrap(), std::fs::read(&preds).unwrap(), train_line, predict_line)
    };
    let (sol_scalar, preds_scalar, train_scalar, pred_scalar) = run("scalar", Some("scalar"));
    let (sol_auto, preds_auto, train_auto, pred_auto) = run("auto", None);
    assert_eq!(
        sol_scalar, sol_auto,
        "persisted model differs between forced-scalar and auto rung"
    );
    assert_eq!(
        preds_scalar, preds_auto,
        "prediction file differs between forced-scalar and auto rung"
    );
    // the reported test error is part of stdout — compare the error=
    // fields too (train timing fields differ, so extract)
    let err = |s: &str| {
        s.split_whitespace()
            .find(|t| t.starts_with("error="))
            .map(str::to_string)
            .unwrap_or_default()
    };
    assert_eq!(err(&train_scalar), err(&train_auto), "train error= differs");
    assert_eq!(err(&pred_scalar), err(&pred_auto), "predict error= differs");
    std::fs::remove_dir_all(&dir).ok();
}
