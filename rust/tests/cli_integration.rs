//! Integration tests for the `liquidsvm` CLI binary.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_liquidsvm"))
}

#[test]
fn help_prints_usage() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("train"));
}

#[test]
fn list_datasets_contains_catalogue() {
    let out = bin().arg("list-datasets").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["banana-mc", "covtype", "webspam"] {
        assert!(text.contains(name), "missing {name} in: {text}");
    }
}

#[test]
fn train_banana_mc_smoke() {
    let out = bin()
        .args(["train", "--data", "banana-mc", "--n", "300", "--folds", "3", "--scenario", "mc"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("error="), "no error report: {text}");
}

#[test]
fn train_with_cells_and_libsvm_grid() {
    let out = bin()
        .args([
            "train", "--data", "covtype", "--n", "600", "--folds", "3",
            "--scenario", "binary", "--voronoi", "6,200", "--libsvm-grid",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("cells="));
}

#[test]
fn train_sparse_smoke() {
    let out = bin()
        .args([
            "train", "--sparse", "--n", "200", "--dim", "5000", "--density", "0.002",
            "--folds", "2", "--scenario", "binary",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("sparse=1") && text.contains("error="), "{text}");
}

#[test]
fn train_sparse_autodetects_csr_extension_and_roundtrips() {
    let dir = std::env::temp_dir().join(format!("lsvm-cli-sparse-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let data = dir.join("toy.csr");
    // 20 rows of 1-based idx:val text
    let mut text = String::new();
    for i in 0..20 {
        let sign = if i % 2 == 0 { 1 } else { -1 };
        text.push_str(&format!("{sign} {}:0.5 {}:{}.25\n", i % 7 + 1, i % 11 + 3, sign));
    }
    std::fs::write(&data, text).unwrap();
    let sol = dir.join("toy.sol");
    let out = bin()
        .args([
            "train", "--file", data.to_str().unwrap(), "--folds", "2",
            "--scenario", "binary", "--save", sol.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("sparse=1"), "extension auto-detect failed: {text}");
    assert!(sol.exists());

    let out = bin()
        .args([
            "predict", "--model", sol.to_str().unwrap(), "--file", data.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn distributed_smoke() {
    let out = bin()
        .args([
            "distributed", "--data", "covtype", "--n", "1500", "--workers", "3",
            "--coarse-size", "500", "--fine-size", "200", "--folds", "3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("speedup="), "{text}");
}

#[test]
fn train_save_bundle_then_predict_from_bundle() {
    let dir = std::env::temp_dir().join(format!("lsvm-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let bundle = dir.join("banana.sol.d");
    // exercises --cells/--jobs and the `--key=value` spelling
    let out = bin()
        .args([
            "train", "--data", "banana", "--n=300", "--folds", "2", "--scenario",
            "binary", "--cells", "1,80", "--jobs=2", "--save",
            bundle.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("saved sharded bundle"), "{text}");
    assert!(bundle.join("MANIFEST").is_file(), "bundle has no MANIFEST");

    let out = bin()
        .args([
            "predict", "--model", bundle.to_str().unwrap(), "--data", "banana", "--n", "120",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("error="), "no error report: {text}");
}

#[test]
fn duplicate_option_across_spellings_fails() {
    let out = bin()
        .args(["train", "--n", "100", "--n=200"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("duplicate option"));
}

#[test]
fn unknown_command_fails_cleanly() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn unknown_dataset_fails_cleanly() {
    let out = bin().args(["train", "--data", "nope"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown dataset"));
}

#[test]
fn save_then_predict_roundtrip() {
    let dir = std::env::temp_dir().join(format!("lsvm-cli-sol-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let sol = dir.join("m.sol");
    let out = bin()
        .args([
            "train", "--data", "banana", "--n", "250", "--folds", "3",
            "--scenario", "binary", "--save", sol.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "train: {}", String::from_utf8_lossy(&out.stderr));
    assert!(sol.exists());

    let preds = dir.join("preds.txt");
    let out = bin()
        .args([
            "predict", "--model", sol.to_str().unwrap(), "--data", "banana",
            "--n", "100", "--out", preds.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "predict: {}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&preds).unwrap();
    // predict's test split is n-test = n/2 = 50 rows
    assert_eq!(text.lines().count(), 50);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn convert_csv_to_libsvm() {
    let dir = std::env::temp_dir().join(format!("lsvm-cli-conv-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("d.csv");
    std::fs::write(&csv, "1,0.5,0\n-1,0,2.5\n").unwrap();
    let light = dir.join("d.libsvm");
    let out = bin()
        .args(["convert", "--in", csv.to_str().unwrap(), "--out", light.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&light).unwrap();
    assert!(text.contains("1:0.5"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn csv_file_input_works() {
    let dir = std::env::temp_dir().join(format!("lsvm-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("toy.csv");
    // 40 separable samples
    let mut text = String::new();
    for i in 0..40 {
        let (y, x) = if i % 2 == 0 { (1.0, 1.0 + (i as f32) * 0.01) } else { (-1.0, -1.0 - (i as f32) * 0.01) };
        text.push_str(&format!("{y},{x},{}\n", x * 0.5));
    }
    std::fs::write(&path, text).unwrap();
    let out = bin()
        .args(["train", "--file", path.to_str().unwrap(), "--scenario", "binary", "--folds", "2"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    std::fs::remove_dir_all(&dir).ok();
}
