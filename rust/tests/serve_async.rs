//! End-to-end tests of the async serving plane (DESIGN.md
//! §Serving-async): binary-vs-text prediction parity over real TCP,
//! hello negotiation and fallback, frame-level error handling on
//! hostile input, the admission-control seams (`max_conns` cap and
//! per-client rate limit), and the event-driven swarm load generator.
//!
//! These tests ride the same frozen surface as `serve_integration.rs`
//! — `Server::start` + raw `TcpStream`s — so they exercise the epoll
//! reactor path exactly as an external client would.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use liquid_svm::data::synth;
use liquid_svm::prelude::*;
use liquid_svm::serve::protocol::{
    self, decode_err_payload, encode_predict_payload, encode_serve_frame, parse_serve_hello_ack,
    read_serve_frame, serve_hello_line, ServeFrameTag, WireMode,
};
use liquid_svm::serve::{run_load_mode, run_swarm, LoadSpec, ServeConfig, Server};

fn train_banana() -> SvmModel {
    let d = synth::banana_binary(150, 71);
    svm_binary(&d, 0.5, &Config::default().folds(2)).unwrap()
}

fn small_cfg() -> ServeConfig {
    ServeConfig {
        port: 0,
        max_batch: 8,
        max_delay: Duration::from_millis(1),
        workers: 2,
        ..ServeConfig::default()
    }
}

/// Start a server with `cfg` and a trained banana model under the
/// name `banana`.
fn serve_banana(cfg: ServeConfig) -> Server {
    let server = Server::start(cfg).unwrap();
    server.registry.insert("banana", train_banana());
    server
}

/// A raw binary-mode client: negotiates the hello, then speaks
/// length-prefixed frames only.
struct BinClient {
    stream: TcpStream,
}

impl BinClient {
    fn connect(addr: std::net::SocketAddr) -> BinClient {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut c = BinClient { stream };
        c.stream
            .write_all(format!("{}\n", serve_hello_line(WireMode::Binary)).as_bytes())
            .unwrap();
        let mut reader = BufReader::new(c.stream.try_clone().unwrap());
        let mut ack = String::new();
        reader.read_line(&mut ack).unwrap();
        assert_eq!(parse_serve_hello_ack(ack.trim()).unwrap(), WireMode::Binary, "{ack}");
        c
    }

    fn send(&mut self, tag: ServeFrameTag, payload: &[u8]) {
        let frame = encode_serve_frame(tag, payload).unwrap();
        self.stream.write_all(&frame).unwrap();
    }

    fn recv(&mut self) -> (ServeFrameTag, Vec<u8>) {
        read_serve_frame(&mut self.stream).unwrap()
    }

    #[allow(clippy::result_large_err)]
    fn predict(
        &mut self,
        model: &str,
        dim: usize,
        rows: &[f32],
    ) -> Result<Vec<f32>, (String, String)> {
        let n = if dim == 0 { 0 } else { rows.len() / dim };
        let payload = encode_predict_payload(model, dim, n, rows).unwrap();
        self.send(ServeFrameTag::Predict, &payload);
        match self.recv() {
            (ServeFrameTag::Decisions, body) => Ok(protocol::bytes_to_f32s(&body).unwrap()),
            (ServeFrameTag::Err, body) => Err(decode_err_payload(&body).unwrap()),
            (tag, _) => panic!("unexpected reply tag {tag:?}"),
        }
    }
}

/// A line-oriented text client (no hello: text is the default).
struct TextClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TextClient {
    fn connect(addr: std::net::SocketAddr) -> TextClient {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        TextClient { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn roundtrip(&mut self, req: &str) -> String {
        writeln!(self.writer, "{req}").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    }
}

/// The acceptance gate: binary-mode decisions are bit-identical to
/// the text protocol's and to in-process `predict`, row by row.
#[test]
fn binary_and_text_predictions_are_bit_identical() {
    let model = train_banana();
    let test = synth::banana_binary(24, 72);
    let expect = model.predict(&test.x);
    let server = Server::start(small_cfg()).unwrap();
    server.registry.insert("banana", model);

    let mut bin = BinClient::connect(server.addr());
    let mut txt = TextClient::connect(server.addr());

    // per-row: one frame vs one line
    for i in 0..test.len() {
        let row = test.x.row(i);
        let got_bin = bin.predict("banana", 2, row).unwrap();
        assert_eq!(got_bin.len(), 1);
        let resp = txt.roundtrip(&format!("predict banana {},{}", row[0], row[1]));
        let got_txt: f32 =
            resp.strip_prefix("ok ").unwrap_or_else(|| panic!("{resp}")).parse().unwrap();
        assert_eq!(got_bin[0].to_bits(), expect[i].to_bits(), "row {i} binary vs direct");
        assert_eq!(got_txt.to_bits(), expect[i].to_bits(), "row {i} text vs direct");
    }

    // one multi-row frame answers every row in order, still bit-exact
    let flat: Vec<f32> = (0..test.len()).flat_map(|i| test.x.row(i).to_vec()).collect();
    let got = bin.predict("banana", 2, &flat).unwrap();
    assert_eq!(got.len(), expect.len());
    for (i, (g, e)) in got.iter().zip(expect.iter()).enumerate() {
        assert_eq!(g.to_bits(), e.to_bits(), "batched row {i}");
    }

    // ping still works in both modes after the traffic
    bin.send(ServeFrameTag::Ping, &[]);
    assert_eq!(bin.recv().0, ServeFrameTag::Pong);
    assert_eq!(txt.roundtrip("ping"), "ok pong");
    server.shutdown();
}

/// Hello negotiation: an unknown mode falls back to text (the ack
/// says so), and a connection that never sends a hello is plain text.
#[test]
fn hello_negotiation_falls_back_to_text() {
    let server = serve_banana(small_cfg());

    let mut c = TextClient::connect(server.addr());
    let ack = c.roundtrip("serve-hello v1 quantum");
    assert_eq!(parse_serve_hello_ack(&ack).unwrap(), WireMode::Text, "{ack}");
    assert!(c.roundtrip("predict banana 0.1,0.2").starts_with("ok "), "text after fallback");

    // no hello at all: first line is treated as a normal request
    let mut c2 = TextClient::connect(server.addr());
    assert_eq!(c2.roundtrip("ping"), "ok pong");
    server.shutdown();
}

/// Quit frame gets a Bye frame and an orderly close.
#[test]
fn binary_quit_answers_bye_then_eof() {
    let server = serve_banana(small_cfg());
    let mut bin = BinClient::connect(server.addr());
    bin.send(ServeFrameTag::Quit, &[]);
    assert_eq!(bin.recv().0, ServeFrameTag::Bye);
    let mut rest = Vec::new();
    bin.stream.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "bytes after bye: {rest:?}");
    server.shutdown();
}

/// Hostile input on the binary path: an unknown tag and an oversized
/// length header each produce one Err frame and a clean close — no
/// hang, no partial garbage — and the server keeps serving others.
#[test]
fn bad_frames_close_cleanly_without_killing_the_server() {
    let server = serve_banana(small_cfg());

    // unknown tag
    let mut c = BinClient::connect(server.addr());
    c.stream.write_all(&[0x7f, 4, 0, 0, 0, 1, 2, 3, 4]).unwrap();
    let (tag, body) = c.recv();
    assert_eq!(tag, ServeFrameTag::Err);
    let (code, _msg) = decode_err_payload(&body).unwrap();
    assert_eq!(code, "bad-frame");
    let mut rest = Vec::new();
    c.stream.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());

    // length header beyond FRAME_MAX: refused from the 5-byte peek,
    // before any payload allocation
    let mut c = BinClient::connect(server.addr());
    let huge = (protocol::FRAME_MAX as u32) + 1;
    let mut frame = vec![ServeFrameTag::Predict as u8];
    frame.extend_from_slice(&huge.to_le_bytes());
    c.stream.write_all(&frame).unwrap();
    let (tag, body) = c.recv();
    assert_eq!(tag, ServeFrameTag::Err);
    let (code, _msg) = decode_err_payload(&body).unwrap();
    assert_eq!(code, "bad-frame");

    // a decodable frame with a lying shape gets a bad-request, and
    // the connection survives it (shape errors are not framing errors)
    let mut c = BinClient::connect(server.addr());
    let err = c.predict("banana", 0, &[]).unwrap_err();
    assert_eq!(err.0, "bad-request", "{err:?}");
    assert!(c.predict("banana", 2, &[0.1, 0.2]).is_ok(), "conn survives shape error");

    // the server is still healthy for everyone else
    let mut txt = TextClient::connect(server.addr());
    assert_eq!(txt.roundtrip("ping"), "ok pong");
    server.shutdown();
}

/// `max_conns` admission: excess accepts get `err conn-limit …` and a
/// close; a freed slot is reusable.
#[test]
fn max_conns_cap_rejects_and_recovers() {
    let server = serve_banana(ServeConfig { max_conns: 2, ..small_cfg() });

    let mut a = TextClient::connect(server.addr());
    assert_eq!(a.roundtrip("ping"), "ok pong");
    let mut b = TextClient::connect(server.addr());
    assert_eq!(b.roundtrip("ping"), "ok pong");

    // third connection: one protocol error line, then EOF
    let mut c = TextClient::connect(server.addr());
    let mut line = String::new();
    c.reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("err conn-limit"), "{line}");
    assert!(line.contains("retry_after_ms="), "{line}");
    let mut rest = String::new();
    c.reader.read_to_string(&mut rest).unwrap();
    assert!(rest.is_empty(), "bytes after conn-limit: {rest:?}");

    // free a slot and retry: the reactor notices the close and
    // releases admission (event-driven, so allow it a moment)
    drop(b);
    let mut admitted = false;
    for _ in 0..200 {
        let mut d = TextClient::connect(server.addr());
        let _ = writeln!(d.writer, "ping"); // may race the reject-close
        let mut first = String::new();
        match d.reader.read_line(&mut first) {
            Ok(_) if first.trim() == "ok pong" => {
                admitted = true;
                break;
            }
            _ => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    assert!(admitted, "slot never recycled after close");
    assert_eq!(a.roundtrip("ping"), "ok pong", "survivor conn unaffected");
    server.shutdown();
}

/// Per-client token bucket: a burst beyond the budget is refused with
/// a machine-readable retry hint, on both wire formats.
#[test]
fn rate_limit_refuses_with_retry_hint() {
    let server = serve_banana(ServeConfig { rate_limit: 4, ..small_cfg() });

    // text: the full burst (4 rows/s) passes, the next row is refused
    let mut txt = TextClient::connect(server.addr());
    let resp = txt.roundtrip("predict banana 0.1,0.2;0.3,0.4;0.5,0.6;0.7,0.8");
    assert!(resp.starts_with("ok "), "{resp}");
    let resp = txt.roundtrip("predict banana 0.9,1.0");
    assert!(resp.starts_with("err rate-limited"), "{resp}");
    assert!(resp.contains("retry_after_ms="), "{resp}");
    // the connection survives the refusal
    assert_eq!(txt.roundtrip("ping"), "ok pong");
    drop(txt);

    // binary, from the same client IP: bucket is shared, still dry
    let mut bin = BinClient::connect(server.addr());
    let err = bin.predict("banana", 2, &[0.1, 0.2]).unwrap_err();
    assert_eq!(err.0, "rate-limited", "{err:?}");
    assert!(err.1.contains("retry_after_ms="), "{err:?}");
    server.shutdown();
}

/// The swarm generator round-trips a few hundred connections from a
/// handful of event-loop threads with strict accounting: every
/// request is answered, every answer matches in-process predict.
#[test]
fn swarm_accounts_for_every_reply_in_both_modes() {
    let model = train_banana();
    let test = synth::banana_binary(40, 73);
    let rows: Vec<Vec<f32>> = (0..test.len()).map(|i| test.x.row(i).to_vec()).collect();
    let expect = model.predict(&test.x);
    let server = Server::start(ServeConfig { workers: 4, ..small_cfg() }).unwrap();
    server.registry.insert("banana", model);

    for mode in [WireMode::Text, WireMode::Binary] {
        let spec = LoadSpec {
            addr: server.addr().to_string(),
            model: "banana".into(),
            connections: 64,
            requests: 8,
            pipeline: 4,
        };
        let report = run_swarm(&spec, &rows, Some(&expect), mode).unwrap();
        assert_eq!(report.ok, 64 * 8, "{mode:?}: {report:?}");
        assert_eq!(report.failed, 0, "{mode:?}: {report:?}");
        assert_eq!(report.mismatches, 0, "{mode:?}: {report:?}");
    }

    // and the thread-per-connection loader agrees in binary mode
    let spec = LoadSpec {
        addr: server.addr().to_string(),
        model: "banana".into(),
        connections: 4,
        requests: 16,
        pipeline: 2,
    };
    let report = run_load_mode(&spec, &rows, Some(&expect), WireMode::Binary).unwrap();
    assert_eq!(report.ok, 4 * 16, "{report:?}");
    assert_eq!(report.mismatches, 0, "{report:?}");
    server.shutdown();
}
