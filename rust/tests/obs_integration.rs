//! Integration tests of the observability plane (DESIGN.md
//! §Observability): the phase-table accounting identity on a real
//! training run, the serve `metrics` command's Prometheus/JSON wire
//! formats, and a golden parse of the `stats` line.
//!
//! The phase table and enable flag are process-global, so every test
//! that could record spans (training, or a live server answering
//! predictions) serializes on [`obs_guard`].

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use liquid_svm::data::synth;
use liquid_svm::obs;
use liquid_svm::prelude::*;
use liquid_svm::serve::{ServeConfig, Server};

fn obs_guard() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poison) => poison.into_inner(),
    }
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn roundtrip(&mut self, req: &str) -> String {
        writeln!(self.writer, "{req}").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line.trim_end().to_string()
    }

    fn read_line(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line.trim_end().to_string()
    }
}

fn serve_model(max_batch: usize) -> (Server, Client) {
    let d = synth::banana_binary(150, 61);
    let model = svm_binary(&d, 0.5, &Config::default().folds(2)).unwrap();
    let server = Server::start(ServeConfig {
        port: 0,
        max_batch,
        max_delay: Duration::from_millis(1),
        workers: 2,
        ..ServeConfig::default()
    })
    .unwrap();
    server.registry.insert("banana", model);
    let client = Client::connect(server.addr());
    (server, client)
}

/// The acceptance identity: on a single-threaded traced training run,
/// the per-phase self times partition the root's wall time — Σself
/// must land within 10% of the measured wall.
#[test]
fn traced_train_self_times_partition_the_wall() {
    let _g = obs_guard();
    let train = synth::banana_binary(300, 41);
    let cfg = Config::default().folds(3).threads(1);
    // warm-up untraced (allocator, page faults), then the traced run
    let _ = svm_binary(&train, 0.5, &cfg).unwrap();

    obs::set_enabled(true);
    obs::reset();
    let t0 = Instant::now();
    let _ = svm_binary(&train, 0.5, &cfg).unwrap();
    let wall_us = t0.elapsed().as_micros() as u64;
    obs::set_enabled(false);

    let rows = obs::phases();
    assert!(!rows.is_empty(), "traced run recorded no phases");
    let names: Vec<&str> = rows.iter().map(|(n, _)| *n).collect();
    for expect in ["train", "train.cells", "train.grid", "cv.run", "gram.fill", "solver.solve"] {
        assert!(names.contains(&expect), "missing phase {expect} in {names:?}");
    }

    let sum_self: u64 = rows.iter().map(|(_, s)| s.self_us).sum();
    let root = rows.iter().find(|(n, _)| *n == "train").unwrap().1;
    assert!(root.total_us <= wall_us, "root {root:?} exceeds wall {wall_us}");
    // Σself telescopes to the roots' totals; everything outside the
    // `train` span (arg handling here, a few µs) is the only slack
    let lo = wall_us as f64 * 0.9;
    let hi = wall_us as f64 * 1.1;
    assert!(
        (sum_self as f64) >= lo && (sum_self as f64) <= hi,
        "Σself {sum_self}µs not within 10% of wall {wall_us}µs: {rows:?}"
    );
    obs::reset();
}

/// `metrics` returns a multi-line Prometheus exposition under the
/// `ok metrics lines=<N>` framing, covering every registered global
/// metric and every serve-level family.
#[test]
fn serve_metrics_exposition_covers_every_registered_metric() {
    let _g = obs_guard();
    let (server, mut c) = serve_model(8);

    // traffic so counters are non-trivial
    assert!(c.roundtrip("predict banana 0.1,0.2").starts_with("ok "));
    assert!(c.roundtrip("predict banana 0.3,-0.4").starts_with("ok "));

    let head = c.roundtrip("metrics");
    let n: usize = head
        .strip_prefix("ok metrics lines=")
        .unwrap_or_else(|| panic!("bad metrics header `{head}`"))
        .parse()
        .unwrap();
    assert!(n > 0);
    let body: Vec<String> = (0..n).map(|_| c.read_line()).collect();
    let text = body.join("\n");

    // every global registry metric appears…
    for name in obs::registry::global().names() {
        assert!(text.contains(&name), "global metric {name} missing from exposition");
    }
    // …and every serve-level family
    for name in [
        "liquidsvm_serve_uptime_seconds",
        "liquidsvm_serve_models",
        "liquidsvm_serve_requests",
        "liquidsvm_serve_rejected",
        "liquidsvm_serve_errors",
        "liquidsvm_serve_slow_requests",
        "liquidsvm_serve_batches",
        "liquidsvm_serve_batched_rows",
        "liquidsvm_serve_padded_rows",
        "liquidsvm_serve_conns_accepted",
        "liquidsvm_serve_conns_rejected",
        "liquidsvm_serve_conns_rate_limited",
        "liquidsvm_serve_conns_open",
        "liquidsvm_serve_shard_resident_bytes",
        "liquidsvm_serve_request_latency_us",
    ] {
        assert!(text.contains(name), "serve metric {name} missing from exposition");
    }

    // exposition-format shape: counters carry the `_total` suffix with
    // HELP/TYPE comments, histograms end in a +Inf bucket + sum/count
    assert!(text.contains("# TYPE liquidsvm_serve_requests_total counter"), "{text}");
    assert!(text.contains("# HELP liquidsvm_serve_requests_total"), "{text}");
    assert!(text.contains("# TYPE liquidsvm_serve_uptime_seconds gauge"), "{text}");
    assert!(text.contains("liquidsvm_serve_request_latency_us_bucket{le=\"+Inf\"} 2"), "{text}");
    assert!(text.contains("liquidsvm_serve_request_latency_us_count 2"), "{text}");

    // every sample line parses as `name[{labels}] value`
    let mut samples = 0;
    for line in &body {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (_, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad sample `{line}`"));
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in `{line}`"));
        assert!(v.is_finite() && v >= 0.0, "{line}");
        samples += 1;
    }
    assert!(samples >= 21, "suspiciously few samples: {samples}");

    // the two accepted rows are visible in the counter sample
    assert!(text.contains("liquidsvm_serve_requests_total 2"), "{text}");

    // the stream is still usable after the multi-line response
    assert_eq!(c.roundtrip("ping"), "ok pong");
    server.shutdown();
}

/// `metrics json` answers on a single line with every family present.
#[test]
fn serve_metrics_json_is_single_line() {
    let _g = obs_guard();
    let (server, mut c) = serve_model(8);
    let resp = c.roundtrip("metrics json");
    let body = resp.strip_prefix("ok ").unwrap_or_else(|| panic!("bad resp `{resp}`"));
    assert!(body.starts_with('{') && body.ends_with('}'), "{body}");
    assert!(!body.contains('\n'));
    for name in obs::registry::global().names() {
        assert!(body.contains(&format!("\"{name}\"")), "{name} missing from json");
    }
    assert!(body.contains("\"liquidsvm_serve_requests\""), "{body}");
    assert!(body.contains("\"liquidsvm_serve_request_latency_us\""), "{body}");
    assert!(c.roundtrip("metrics xml").starts_with("err "));
    server.shutdown();
}

/// Golden parse of the `stats` wire format: one `ok`-prefixed line of
/// space-separated `key=value` tokens with the documented keys, whose
/// values parse under the documented shapes.
#[test]
fn stats_line_parses_token_by_token() {
    let _g = obs_guard();
    let (server, mut c) = serve_model(8);
    assert!(c.roundtrip("predict banana 0.5,0.5").starts_with("ok "));
    assert!(c.roundtrip("predict banana 1.0,-1.0;0.2,0.1").starts_with("ok "));

    let resp = c.roundtrip("stats");
    let body = resp.strip_prefix("ok ").unwrap_or_else(|| panic!("bad resp `{resp}`"));
    let mut kv = std::collections::HashMap::new();
    for tok in body.split_whitespace() {
        let (k, v) = tok
            .split_once('=')
            .unwrap_or_else(|| panic!("token `{tok}` is not key=value in `{body}`"));
        assert!(kv.insert(k, v).is_none(), "duplicate key {k}");
    }

    // integer-valued keys
    for key in [
        "models", "uptime_s", "requests", "rejected", "errors", "slow", "conns",
        "conns_accepted", "conns_rejected", "rate_limited", "batches", "rows",
        "pad_rows", "p50_us", "p95_us", "p99_us", "max_us", "mean_us", "shard_hits",
        "shard_loads", "shard_evictions", "gram_hits", "gram_misses", "gram_allocs", "xla_calls",
        "solver_sweeps", "shrink_active", "unshrink_passes", "cell_units", "cell_train_us",
    ] {
        let v = kv.get(key).unwrap_or_else(|| panic!("missing {key} in `{body}`"));
        v.parse::<u64>().unwrap_or_else(|_| panic!("{key}={v} is not an integer"));
    }
    // float-valued keys
    for key in ["mean_batch", "rps"] {
        let v = kv.get(key).unwrap_or_else(|| panic!("missing {key} in `{body}`"));
        v.parse::<f64>().unwrap_or_else(|_| panic!("{key}={v} is not a float"));
    }
    // ratio-shaped keys: `a/b`
    for key in ["shards", "shard_bytes"] {
        let v = kv.get(key).unwrap_or_else(|| panic!("missing {key} in `{body}`"));
        let (a, b) = v.split_once('/').unwrap_or_else(|| panic!("{key}={v} is not a/b"));
        a.parse::<u64>().unwrap();
        b.parse::<u64>().unwrap();
    }
    // per-model routing: `name:rows[,name:rows]` after three rows
    let mr = kv["model_rows"];
    assert_eq!(mr, "banana:3", "model_rows `{mr}`");
    assert_eq!(kv["models"], "1");
    assert_eq!(kv["requests"], "3");
    server.shutdown();
}
