//! Property-style tests over randomized inputs (hand-rolled — the
//! offline registry has no proptest; each property runs a deterministic
//! sweep of seeded random cases and asserts the invariant on all).
//!
//! Coordinator invariants under test: cell decompositions cover &
//! route correctly for every strategy/shape; fold generation partitions
//! for every kind/k/n; solvers respect their dual constraints on random
//! problems; prediction combination emits valid labels; IO round-trips.

use liquid_svm::cells::{make_cells, CellStrategy};
use liquid_svm::data::folds::{make_folds, FoldKind};
use liquid_svm::data::matrix::Matrix;
use liquid_svm::data::rng::Rng;
use liquid_svm::data::synth;
use liquid_svm::data::Dataset;
use liquid_svm::kernel::{GramBackend, KernelKind};
use liquid_svm::solver::{solve_dense, SolverKind, SolverParams};
use liquid_svm::tasks::{combine_predictions, create_tasks, TaskSpec};

const CASES: u64 = 12;

fn random_dataset(rng: &mut Rng, n: usize, d: usize, classes: usize) -> Dataset {
    let x = Matrix::from_vec((0..n * d).map(|_| rng.range(-3.0, 3.0)).collect(), n, d);
    let y = (0..n)
        .map(|_| {
            if classes == 2 {
                if rng.uniform() < 0.5 { -1.0 } else { 1.0 }
            } else {
                rng.below(classes) as f32
            }
        })
        .collect();
    Dataset::new(x, y)
}

#[test]
fn prop_cells_cover_every_sample() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let n = 50 + rng.below(400);
        let d = 1 + rng.below(8);
        let data = random_dataset(&mut rng, n, d, 2);
        let size = 20 + rng.below(100);
        for strategy in [
            CellStrategy::None,
            CellStrategy::RandomChunks { size },
            CellStrategy::Voronoi { size },
            CellStrategy::RecursiveTree { max_size: size.max(8) },
        ] {
            let p = make_cells(&data, &strategy, seed);
            let mut seen = vec![false; n];
            for cell in &p.cells {
                for &i in cell {
                    assert!(!seen[i], "{strategy:?}: duplicate {i} (seed {seed})");
                    seen[i] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "{strategy:?}: missing samples (seed {seed})");
        }
    }
}

#[test]
fn prop_every_training_point_routes_to_an_owning_cell() {
    // the invariant sharded serving rests on: a point that trained in
    // shard c must route back to a cell that contains it, under every
    // strategy (for the broadcast router "routes to" means the owner
    // is among the broadcast set; for overlapping Voronoi the owner is
    // the base cell, which keeps its members when cells grow)
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x5e11);
        let n = 60 + rng.below(300);
        let d = 2 + rng.below(5);
        let data = random_dataset(&mut rng, n, d, 2);
        let size = 20 + rng.below(80);
        for strategy in [
            CellStrategy::None,
            CellStrategy::RandomChunks { size },
            CellStrategy::Voronoi { size },
            CellStrategy::OverlappingVoronoi { size, overlap: 0.3 },
            CellStrategy::RecursiveTree { max_size: size.max(8) },
        ] {
            let p = make_cells(&data, &strategy, seed);
            for i in 0..n {
                let routed = p.route(data.x.row(i));
                assert!(
                    routed.iter().any(|&c| p.cells[c].contains(&i)),
                    "{strategy:?}: sample {i} routed to {routed:?}, none of which owns it \
                     (seed {seed})"
                );
            }
        }
    }
}

#[test]
fn prop_overlapping_cells_superset_of_voronoi() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x10);
        let n = 80 + rng.below(200);
        let data = random_dataset(&mut rng, n, 3, 2);
        let p = make_cells(&data, &CellStrategy::OverlappingVoronoi { size: 50, overlap: 0.4 }, seed);
        // overlap cells still cover everything (possibly more than once)
        let mut seen = vec![false; n];
        for cell in &p.cells {
            for &i in cell {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "overlap cells dropped samples (seed {seed})");
    }
}

#[test]
fn prop_routing_is_deterministic_and_in_range() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x20);
        let n = 60 + rng.below(300);
        let data = random_dataset(&mut rng, n, 4, 2);
        for strategy in [
            CellStrategy::Voronoi { size: 40 },
            CellStrategy::RecursiveTree { max_size: 40 },
        ] {
            let p = make_cells(&data, &strategy, seed);
            for i in 0..n.min(30) {
                let a = p.route(data.x.row(i));
                let b = p.route(data.x.row(i));
                assert_eq!(a, b, "routing not deterministic");
                for &c in &a {
                    assert!(c < p.n_cells());
                }
            }
        }
    }
}

#[test]
fn prop_folds_partition_for_all_kinds() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x30);
        let n = 20 + rng.below(300);
        let k = 2 + rng.below(6);
        if n < k {
            continue;
        }
        let data = random_dataset(&mut rng, n, 2, 2);
        for kind in [FoldKind::Random, FoldKind::Stratified, FoldKind::Block, FoldKind::Alternating] {
            let f = make_folds(&data, k, kind, seed);
            let mut seen = vec![0u8; n];
            for fold in &f.folds {
                for &i in fold {
                    seen[i] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{kind:?} not a partition (n={n}, k={k})");
            // no empty folds
            assert!(f.folds.iter().all(|fo| !fo.is_empty()), "{kind:?} empty fold");
        }
    }
}

#[test]
fn prop_hinge_alpha_always_in_box() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x40);
        let n = 20 + rng.below(60);
        let data = random_dataset(&mut rng, n, 3, 2);
        let k = GramBackend::Blocked.gram(&data.x, &data.x, 1.5, KernelKind::Gauss);
        let lambda = 10f32.powf(rng.range(-4.0, -1.0));
        let w = rng.range(0.2, 0.8);
        let sol = solve_dense(SolverKind::Hinge { w }, &k, &data.y, lambda, &SolverParams::default(), None);
        let c = 1.0 / (2.0 * lambda * n as f32);
        for (coef, &yi) in sol.coef.iter().zip(&data.y) {
            let a = coef * yi;
            let hi = if yi > 0.0 { 2.0 * w * c } else { 2.0 * (1.0 - w) * c };
            assert!(
                (-1e-5..=hi + 1e-5).contains(&a),
                "alpha {a} outside [0, {hi}] (seed {seed})"
            );
        }
    }
}

#[test]
fn prop_quantile_beta_in_box_and_ls_residual_small() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x50);
        let n = 20 + rng.below(50);
        let d = synth::sinc_hetero(n, seed);
        let k = GramBackend::Blocked.gram(&d.x, &d.x, 0.9, KernelKind::Gauss);
        let lambda = 10f32.powf(rng.range(-4.0, -2.0));
        let tau = rng.range(0.1, 0.9);
        let sol = solve_dense(SolverKind::Quantile { tau }, &k, &d.y, lambda, &SolverParams::default(), None);
        let c = 1.0 / (2.0 * lambda * n as f32);
        for &b in &sol.coef {
            assert!(b >= c * (tau - 1.0) - 1e-5 && b <= c * tau + 1e-5, "beta {b} (seed {seed})");
        }
    }
}

#[test]
fn prop_warm_start_never_worse_objective() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x60);
        let n = 30 + rng.below(50);
        let data = random_dataset(&mut rng, n, 2, 2);
        let k = GramBackend::Blocked.gram(&data.x, &data.x, 1.0, KernelKind::Gauss);
        let p = SolverParams::default();
        let l1 = 1e-2f32;
        let l2 = 5e-3f32;
        let first = solve_dense(SolverKind::Hinge { w: 0.5 }, &k, &data.y, l1, &p, None);
        let warm_vec = liquid_svm::solver::warm_vector(SolverKind::Hinge { w: 0.5 }, &first, &data.y);
        let warm = solve_dense(SolverKind::Hinge { w: 0.5 }, &k, &data.y, l2, &p, Some(&warm_vec));
        let cold = solve_dense(SolverKind::Hinge { w: 0.5 }, &k, &data.y, l2, &p, None);
        // same KKT tolerance ⇒ same objective up to tolerance slack
        assert!(
            (warm.objective - cold.objective).abs() <= 2e-2 * (1.0 + cold.objective.abs()),
            "warm {} vs cold {} (seed {seed})",
            warm.objective,
            cold.objective
        );
    }
}

#[test]
fn prop_combined_predictions_are_valid_labels() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x70);
        let n_classes = 3 + rng.below(4);
        let classes: Vec<f32> = (0..n_classes).map(|c| c as f32).collect();
        let n = 20 + rng.below(40);
        for spec in [TaskSpec::MultiClassOvA, TaskSpec::MultiClassAvA] {
            let n_tasks = match spec {
                TaskSpec::MultiClassOvA => n_classes,
                _ => n_classes * (n_classes - 1) / 2,
            };
            let scores: Vec<Vec<f32>> = (0..n_tasks)
                .map(|_| (0..n).map(|_| rng.range(-2.0, 2.0)).collect())
                .collect();
            let preds = combine_predictions(&spec, &classes, &scores);
            assert_eq!(preds.len(), n);
            for p in preds {
                assert!(classes.contains(&p), "invalid label {p} (seed {seed})");
            }
        }
    }
}

#[test]
fn prop_task_indices_and_labels_consistent() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x80);
        let n_classes = 2 + rng.below(5);
        let n = 30 + rng.below(100);
        let data = random_dataset(&mut rng, n, 3, n_classes);
        for spec in [TaskSpec::MultiClassOvA, TaskSpec::MultiClassAvA] {
            for task in create_tasks(&data, &spec) {
                assert_eq!(task.indices.len(), task.y.len());
                for &i in &task.indices {
                    assert!(i < data.len());
                }
                for &y in &task.y {
                    assert!(y == 1.0 || y == -1.0, "binary task label {y}");
                }
            }
        }
    }
}

#[test]
fn prop_libsvm_io_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x90);
        let n = 5 + rng.below(40);
        let d = 1 + rng.below(10);
        let data = random_dataset(&mut rng, n, d, 2);
        let dir = std::env::temp_dir().join(format!("lsvm-prop-{}-{seed}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.libsvm");
        liquid_svm::data::io::write_libsvm(&p, &data).unwrap();
        let back = liquid_svm::data::io::read_libsvm(&p, d).unwrap();
        assert_eq!(back.y, data.y);
        for i in 0..n {
            for j in 0..d {
                let (a, b) = (back.x.get(i, j), data.x.get(i, j));
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "({i},{j}): {a} vs {b}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn prop_gram_backends_agree() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xa0);
        let m = 5 + rng.below(60);
        let n = 5 + rng.below(60);
        let d = 1 + rng.below(20);
        let x = Matrix::from_vec((0..m * d).map(|_| rng.range(-2.0, 2.0)).collect(), m, d);
        let y = Matrix::from_vec((0..n * d).map(|_| rng.range(-2.0, 2.0)).collect(), n, d);
        let g = rng.range(0.3, 4.0);
        for kind in [KernelKind::Gauss, KernelKind::Laplace] {
            let a = GramBackend::Scalar.gram(&x, &y, g, kind);
            let b = GramBackend::Blocked.gram(&x, &y, g, kind);
            for (u, v) in a.as_slice().iter().zip(b.as_slice()) {
                assert!((u - v).abs() < 2e-4, "{kind:?}: {u} vs {v} (seed {seed})");
            }
        }
    }
}

#[test]
fn prop_sq_dists_never_negative_across_backends() {
    // near-duplicate rows with large norms trigger cancellation in the
    // blocked path's ‖x‖²+‖y‖²−2⟨x,y⟩; the clamp at the source must
    // keep every backend non-negative and in agreement
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xb0);
        let d = 4 + rng.below(24);
        let base: Vec<f32> = (0..d).map(|_| rng.range(20.0, 80.0)).collect();
        let n = 8 + rng.below(16);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let mut v = base.clone();
                v[r % d] += rng.range(0.0, 1e-3);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let x = Matrix::from_rows(&refs);
        let a = GramBackend::Scalar.sq_dists(&x, &x);
        let b = GramBackend::Blocked.sq_dists(&x, &x);
        for (u, v) in a.as_slice().iter().zip(b.as_slice()) {
            assert!(*u >= 0.0 && *v >= 0.0, "negative d²: scalar {u} blocked {v} (seed {seed})");
            assert!((u - v).abs() < 1e-2 * (1.0 + u.abs()), "{u} vs {v} (seed {seed})");
        }
    }
}

#[test]
fn prop_streamed_gram_bit_identical_to_dense() {
    // the Gram-plane contract: streamed/tiled row access produces the
    // exact bits of the materialized path, for every kernel and CPU
    // backend — this is what makes the memory tiers interchangeable
    use liquid_svm::kernel::plane::{GramSource, StreamedGram, TileBuffer};
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xc0);
        let m = 5 + rng.below(40);
        let n = 5 + rng.below(40);
        let d = 1 + rng.below(12);
        let x = Matrix::from_vec((0..m * d).map(|_| rng.range(-2.0, 2.0)).collect(), m, d);
        let y = Matrix::from_vec((0..n * d).map(|_| rng.range(-2.0, 2.0)).collect(), n, d);
        let g = rng.range(0.3, 4.0);
        let (xn, yn) = (x.row_sq_norms(), y.row_sq_norms());
        for be in [GramBackend::Scalar, GramBackend::Blocked] {
            for kind in [KernelKind::Gauss, KernelKind::Laplace] {
                let dense = be.gram(&x, &y, g, kind);
                let mut s = StreamedGram::new(&be, &x, &y, &xn, &yn, kind, g);
                for i in 0..m {
                    let (want, got) = (dense.row(i), s.row(i));
                    assert_eq!(
                        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{be:?} {kind:?} row {i} (seed {seed})"
                    );
                }
                assert_eq!(s.get(m / 2, n / 2).to_bits(), dense.get(m / 2, n / 2).to_bits());
                // tiled accumulation over a zero-cap (1-row tiles)
                // matches a full cross-Gram dot as well
                let coef: Vec<f32> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
                let mut acc = vec![0.0f32; m];
                let mut buf = TileBuffer::new();
                liquid_svm::kernel::plane::accumulate_decisions(
                    &be, kind, g, &x, &xn, &y, &coef, Some(0), &mut buf, &mut acc,
                );
                for (i, a) in acc.iter().enumerate() {
                    let want: f32 =
                        coef.iter().zip(dense.row(i)).map(|(c, k)| c * k).sum();
                    assert_eq!(a.to_bits(), want.to_bits(), "tile row {i} (seed {seed})");
                }
            }
        }
    }
}

#[test]
fn prop_parallel_cv_bit_identical_to_sequential() {
    // --jobs N must select the same (γ*, λ*) and produce bit-identical
    // fold coefficients as --jobs 1, across solvers and adaptivity
    use liquid_svm::cv::{run_cv, CvConfig, Grid};
    use liquid_svm::metrics::Loss;
    for seed in 0..4u64 {
        let n = 120 + (seed as usize) * 17;
        let (data, solver, loss): (Dataset, SolverKind, Loss) = if seed % 2 == 0 {
            (synth::banana_binary(n, seed), SolverKind::Hinge { w: 0.5 }, Loss::Classification)
        } else {
            (synth::sinc_hetero(n, seed), SolverKind::LeastSquares, Loss::LeastSquares)
        };
        let mut cfg = CvConfig::new(Grid::default_grid(0, n - n / 3, data.dim()), solver, loss);
        cfg.folds = 3;
        cfg.fold_kind = FoldKind::Random;
        cfg.adaptivity = (seed % 3) as u8;
        cfg.seed = seed;
        let seq = run_cv(&data, &cfg);
        let mut par_cfg = cfg.clone();
        par_cfg.jobs = 4;
        let par = run_cv(&data, &par_cfg);
        assert_eq!(seq.best_gamma.to_bits(), par.best_gamma.to_bits(), "seed {seed}");
        assert_eq!(seq.best_lambda.to_bits(), par.best_lambda.to_bits(), "seed {seed}");
        assert_eq!(seq.points_evaluated, par.points_evaluated, "seed {seed}");
        for (a, b) in seq.models.iter().zip(&par.models) {
            assert_eq!(a.train_idx, b.train_idx);
            assert_eq!(
                a.coef.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.coef.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "fold coefficients differ (seed {seed})"
            );
        }
    }
}

#[test]
fn prop_sparse_gram_bit_identical_to_dense_gram() {
    // the sparse data plane's core contract: a SparseGram over a CSR
    // matrix produces the exact bits a DenseGram holds for the
    // densified data — same dot4-order guarantee the streamed path
    // already makes — across both CPU backends and both kernels
    use liquid_svm::data::csr::CsrMatrix;
    use liquid_svm::kernel::plane::{DenseGram, GramSource, SparseGram};
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xd0);
        let m = 5 + rng.below(30);
        let n = 5 + rng.below(30);
        // dims straddling the dot4 lane cut (d % 4 ∈ {0..3})
        let d = 4 + rng.below(40);
        let nnz = 1 + rng.below(6);
        let mut xd = Matrix::zeros(m, d);
        let mut yd = Matrix::zeros(n, d);
        for i in 0..m {
            for _ in 0..nnz {
                let j = rng.below(d);
                xd.set(i, j, rng.range(-2.0, 2.0));
            }
        }
        for i in 0..n {
            for _ in 0..nnz {
                let j = rng.below(d);
                yd.set(i, j, rng.range(-2.0, 2.0));
            }
        }
        let x = CsrMatrix::from_dense(&xd);
        let y = CsrMatrix::from_dense(&yd);
        let (xn, yn) = (x.row_sq_norms(), y.row_sq_norms());
        let g = rng.range(0.3, 4.0);
        for be in [GramBackend::Scalar, GramBackend::Blocked] {
            for kind in [KernelKind::Gauss, KernelKind::Laplace] {
                let dense_k = be.gram(&xd, &yd, g, kind);
                let mut dense = DenseGram::new(&dense_k);
                let mut sparse = SparseGram::new(&be, &x, &y, &xn, &yn, kind, g);
                for i in 0..m {
                    let (a, b) = (dense.row(i), sparse.row(i));
                    for (u, v) in a.iter().zip(b) {
                        assert_eq!(
                            u.to_bits(),
                            v.to_bits(),
                            "{be:?} {kind:?} row {i}: {u} vs {v} (seed {seed})"
                        );
                    }
                }
                // entry access with no resident row
                let mut fresh = SparseGram::new(&be, &x, &y, &xn, &yn, kind, g);
                let (i, j) = (rng.below(m), rng.below(n));
                assert_eq!(fresh.get(i, j).to_bits(), dense.get(i, j).to_bits());
            }
        }
    }
}

#[test]
fn prop_libsvm_csr_roundtrip_preserves_triplet() {
    // CSR write → stream-read round-trip is exact for random sparse
    // data, and the dense reader agrees with the densified CSR
    use liquid_svm::data::io;
    for seed in 0..6u64 {
        let d = synth::sparse_binary(40, 200 + seed as usize * 57, 0.02, seed);
        let dir = std::env::temp_dir().join(format!(
            "lsvm-prop-csr-{}-{seed}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("d.csr");
        io::write_libsvm_csr(&p, &d).unwrap();
        let back = io::read_libsvm_csr(&p, d.dim()).unwrap();
        assert_eq!(back.x, d.x, "seed {seed}");
        assert_eq!(back.y, d.y, "seed {seed}");
        let dense = io::read_libsvm(&p, d.dim()).unwrap();
        assert_eq!(dense.x.as_slice(), d.to_dense().x.as_slice(), "seed {seed}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
