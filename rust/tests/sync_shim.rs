//! Std-mode contract for the `liquid_svm::sync` shim (DESIGN.md
//! §Static-analysis): without `--cfg loom` the shim must re-export
//! `std::sync` types *unchanged* — same `TypeId`, same poisoning
//! behavior — so routing the whole crate through it costs nothing.
//! The loom leg of the contract lives in `tests/loom_models.rs`.

#![cfg(not(loom))]

use std::any::TypeId;

#[test]
fn shim_types_are_std_types() {
    assert_eq!(
        TypeId::of::<liquid_svm::sync::Mutex<u64>>(),
        TypeId::of::<std::sync::Mutex<u64>>()
    );
    assert_eq!(
        TypeId::of::<liquid_svm::sync::RwLock<u64>>(),
        TypeId::of::<std::sync::RwLock<u64>>()
    );
    assert_eq!(TypeId::of::<liquid_svm::sync::Condvar>(), TypeId::of::<std::sync::Condvar>());
    assert_eq!(
        TypeId::of::<liquid_svm::sync::Arc<u64>>(),
        TypeId::of::<std::sync::Arc<u64>>()
    );
    assert_eq!(
        TypeId::of::<liquid_svm::sync::atomic::AtomicU64>(),
        TypeId::of::<std::sync::atomic::AtomicU64>()
    );
    assert_eq!(
        TypeId::of::<liquid_svm::sync::static_atomic::AtomicU64>(),
        TypeId::of::<std::sync::atomic::AtomicU64>()
    );
    assert_eq!(
        TypeId::of::<liquid_svm::sync::mpsc::Sender<u64>>(),
        TypeId::of::<std::sync::mpsc::Sender<u64>>()
    );
    assert_eq!(
        TypeId::of::<liquid_svm::sync::OnceLock<u64>>(),
        TypeId::of::<std::sync::OnceLock<u64>>()
    );
}

#[test]
fn shim_mutex_preserves_poisoning() {
    let m = liquid_svm::sync::Arc::new(liquid_svm::sync::Mutex::new(0u32));
    let m2 = liquid_svm::sync::Arc::clone(&m);
    let _ = std::thread::spawn(move || {
        let _g = m2.lock().unwrap();
        panic!("poison the lock");
    })
    .join();
    // std semantics: a panic while holding the lock poisons it, and
    // the data stays reachable through the poison error
    let err = m.lock().expect_err("poisoned mutex must surface the panic");
    assert_eq!(*err.into_inner(), 0);
}
