//! Integration: the L3↔L1 bridge — AOT Pallas/XLA artifacts executed
//! via PJRT must agree with the CPU backends bit-for-bit (up to f32
//! round-off) and plug into the full pipeline.
//!
//! Requires `make artifacts`; tests skip politely when artifacts are
//! missing (e.g. a cargo-only environment).

use std::sync::Arc;

use liquid_svm::data::rng::Rng;
use liquid_svm::data::Matrix;
use liquid_svm::kernel::{GramBackend, KernelKind};
use liquid_svm::runtime::{default_artifact_dir, XlaRuntime};

fn runtime() -> Option<Arc<XlaRuntime>> {
    XlaRuntime::open(default_artifact_dir()).ok().map(Arc::new)
}

fn randmat(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    Matrix::from_vec((0..rows * cols).map(|_| rng.range(-1.5, 1.5)).collect(), rows, cols)
}

#[test]
fn gram_multi_matches_cpu_backend() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let x = randmat(&mut rng, 100, 9);
    let y = randmat(&mut rng, 150, 9);
    let gammas = [0.5f32, 1.0, 2.0, 5.0];
    let xla = GramBackend::Xla(rt).gram_multi(&x, &y, &gammas, KernelKind::Gauss);
    let cpu = GramBackend::Blocked.gram_multi(&x, &y, &gammas, KernelKind::Gauss);
    for (a, b) in xla.iter().zip(&cpu) {
        for (u, v) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((u - v).abs() < 1e-4, "{u} vs {v}");
        }
    }
}

#[test]
fn gram_multi_tiles_gamma_grids_beyond_chunk() {
    let Some(rt) = runtime() else { return };
    let chunk = rt.manifest().gamma_chunk;
    let mut rng = Rng::new(2);
    let x = randmat(&mut rng, 40, 5);
    // 15 gammas > chunk of 10 forces two artifact invocations
    let gammas: Vec<f32> = (0..chunk + 5).map(|i| 0.3 + 0.2 * i as f32).collect();
    let xla = GramBackend::Xla(rt).gram_multi(&x, &x, &gammas, KernelKind::Gauss);
    let cpu = GramBackend::Blocked.gram_multi(&x, &x, &gammas, KernelKind::Gauss);
    assert_eq!(xla.len(), gammas.len());
    for (a, b) in xla.iter().zip(&cpu) {
        for (u, v) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((u - v).abs() < 1e-4);
        }
    }
}

#[test]
fn predict_artifact_matches_reference() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(3);
    let x = randmat(&mut rng, 64, 12);
    let sv = randmat(&mut rng, 200, 12);
    let alpha = randmat(&mut rng, 200, 3);
    let pred = rt.predict(&x, &sv, &alpha, 1.3).unwrap();
    let k = GramBackend::Blocked.gram(&x, &sv, 1.3, KernelKind::Gauss);
    for i in 0..64 {
        for t in 0..3 {
            let want: f32 = (0..200).map(|j| k.get(i, j) * alpha.get(j, t)).sum();
            assert!((pred.get(i, t) - want).abs() < 1e-3);
        }
    }
}

#[test]
fn predict_tiles_wide_coefficient_blocks() {
    let Some(rt) = runtime() else { return };
    let tcap = rt.manifest().t_cols;
    let mut rng = Rng::new(4);
    let x = randmat(&mut rng, 20, 6);
    let sv = randmat(&mut rng, 50, 6);
    let t = tcap + 3; // forces column tiling
    let alpha = randmat(&mut rng, 50, t);
    let pred = rt.predict(&x, &sv, &alpha, 0.9).unwrap();
    assert_eq!((pred.rows(), pred.cols()), (20, t));
    let k = GramBackend::Blocked.gram(&x, &sv, 0.9, KernelKind::Gauss);
    for i in 0..20 {
        for c in 0..t {
            let want: f32 = (0..50).map(|j| k.get(i, j) * alpha.get(j, c)).sum();
            assert!((pred.get(i, c) - want).abs() < 1e-3);
        }
    }
}

#[test]
fn oversize_shapes_fall_back_to_cpu() {
    let Some(rt) = runtime() else { return };
    let max = rt.max_gram_rows();
    let mut rng = Rng::new(5);
    // rows beyond every bucket: the backend must fall back, not fail
    let x = randmat(&mut rng, max + 10, 4);
    let out = GramBackend::Xla(rt).gram_multi(&x, &x, &[1.0], KernelKind::Gauss);
    assert_eq!(out[0].rows(), max + 10);
    let cpu = GramBackend::Blocked.gram(&x, &x, 1.0, KernelKind::Gauss);
    for (u, v) in out[0].as_slice().iter().zip(cpu.as_slice()) {
        assert!((u - v).abs() < 1e-4);
    }
}

#[test]
fn full_pipeline_with_xla_backend() {
    if runtime().is_none() {
        return;
    }
    use liquid_svm::coordinator::config::BackendChoice;
    use liquid_svm::prelude::*;
    let d = liquid_svm::data::synth::banana_binary(250, 6);
    let cfg = Config::default().folds(3).backend(BackendChoice::Xla);
    let m = svm_binary(&d, 0.5, &cfg).unwrap();
    let test = liquid_svm::data::synth::banana_binary(150, 7);
    let res = m.test(&test);
    assert!(res.error < 0.25, "xla-backend pipeline error {}", res.error);
}

#[test]
fn manifest_parses_and_lists_buckets() {
    let Some(rt) = runtime() else { return };
    let man = rt.manifest();
    assert!(man.gamma_chunk >= 1);
    assert!(man.artifacts.iter().any(|a| a.op == "gram_multi"));
    assert!(man.artifacts.iter().any(|a| a.op == "predict"));
    for a in &man.artifacts {
        assert!(a.rows > 0 && a.cols > 0 && a.dim > 0, "{a:?}");
    }
}
