//! Solver-core contract tests (DESIGN.md §Solver-core).
//!
//! Three families:
//!
//! 1. **Bit-identity to the pre-engine solvers.**  The four per-loss
//!    algorithms that existed before the shared engine are kept here
//!    as reference implementations (verbatim arithmetic, dense Gram).
//!    A shrink-off engine run must reproduce their coefficients and
//!    objectives *bit for bit* on randomized problems — the proof
//!    that the refactor moved code without changing a single float.
//! 2. **Shrink-on ≡ shrink-off parity** for all four losses: same
//!    ε-KKT criterion at exit, so objectives agree within tolerance,
//!    and at the CV level the selected (γ*, λ*) and test error are
//!    preserved.
//! 3. **(γ, λ) warm-start plane**: warm-starting a γ's first λ from
//!    the previous γ-chain's terminal α costs no more iterations than
//!    a cold start, for every loss.

use liquid_svm::data::matrix::Matrix;
use liquid_svm::data::synth;
use liquid_svm::kernel::{GramBackend, KernelKind};
use liquid_svm::solver::{solve_dense, warm_vector, SolverKind, SolverParams};

const CASES: u64 = 8;

fn gram(x: &Matrix, gamma: f32) -> Matrix {
    GramBackend::Blocked.gram(x, x, gamma, KernelKind::Gauss)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn off(p: &SolverParams) -> SolverParams {
    SolverParams { shrink_every: 0, ..*p }
}

// ===================================================================
// Reference implementations: the solvers exactly as they existed
// before the shared engine (pre-refactor arithmetic, dense access).
// ===================================================================

fn ref_box_c(lambda: f32, n: usize) -> f32 {
    1.0 / (2.0 * lambda * n as f32)
}

fn ref_violation(alpha: f32, g: f32, lo: f32, hi: f32) -> f32 {
    let mut v: f32 = 0.0;
    if alpha < hi {
        v = v.max(-g);
    }
    if alpha > lo {
        v = v.max(g);
    }
    v
}

fn ref_clip_step(alpha: f32, g: f32, q: f32, lo: f32, hi: f32) -> f32 {
    let target = alpha - g / q.max(1e-12);
    target.clamp(lo, hi) - alpha
}

/// The pre-engine hinge solver (greedy 2-coordinate, fused sweep).
fn ref_hinge(
    k: &Matrix,
    y: &[f32],
    lambda: f32,
    w: f32,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> (Vec<f32>, f32, usize) {
    let n = y.len();
    let c = ref_box_c(lambda, n);
    let hi: Vec<f32> =
        y.iter().map(|&yi| if yi > 0.0 { 2.0 * w * c } else { 2.0 * (1.0 - w) * c }).collect();
    let mut alpha: Vec<f32> = match warm {
        Some(prev) => prev.iter().zip(&hi).map(|(&a, &h)| a.clamp(0.0, h)).collect(),
        None => vec![0.0; n],
    };
    let mut g: Vec<f32> = vec![-1.0; n];
    for j in 0..n {
        if alpha[j] != 0.0 {
            let aj = alpha[j] * y[j];
            let krow = k.row(j);
            for i in 0..n {
                g[i] += y[i] * aj * krow[i];
            }
        }
    }
    let select = |alpha: &[f32], g: &[f32]| {
        let (mut i1, mut v1) = (usize::MAX, 0.0f32);
        let (mut i2, mut v2) = (usize::MAX, 0.0f32);
        for i in 0..alpha.len() {
            let v = ref_violation(alpha[i], g[i], 0.0, hi[i]);
            if v > v1 {
                i2 = i1;
                v2 = v1;
                i1 = i;
                v1 = v;
            } else if v > v2 {
                i2 = i;
                v2 = v;
            }
        }
        (i1, v1, i2, v2)
    };
    let (mut i1, mut v1, mut i2, mut _v2) = select(&alpha, &g);
    let mut pair_steps = 0usize;
    let mut single_steps = 0usize;
    // the reference counted loop passes; the engine counts coordinate
    // updates (pair = 2) — track both kinds so the caller can compare
    let mut iters = 0usize;
    while iters < params.max_iter {
        if i1 == usize::MAX || v1 <= params.eps {
            break;
        }
        if i2 == usize::MAX || i2 == i1 {
            let d = ref_clip_step(alpha[i1], g[i1], k.get(i1, i1), 0.0, hi[i1]);
            if d != 0.0 {
                alpha[i1] += d;
                let yi_d = y[i1] * d;
                let krow = k.row(i1);
                for (j, gj) in g.iter_mut().enumerate() {
                    *gj += y[j] * yi_d * krow[j];
                }
            }
            (i1, v1, i2, _v2) = select(&alpha, &g);
            iters += 1;
            single_steps += 1;
            continue;
        }
        let q11 = k.get(i1, i1).max(1e-12);
        let q22 = k.get(i2, i2).max(1e-12);
        let q12 = y[i1] * y[i2] * k.get(i1, i2);
        let (g1, g2) = (g[i1], g[i2]);
        let det = q11 * q22 - q12 * q12;
        let (mut d1, mut d2);
        if det > 1e-12 * q11 * q22 {
            d1 = (-g1 * q22 + g2 * q12) / det;
            d2 = (-g2 * q11 + g1 * q12) / det;
        } else {
            d1 = -g1 / q11;
            d2 = 0.0;
        }
        let in_box = |a: f32, lo: f32, hi_: f32| a >= lo - 1e-12 && a <= hi_ + 1e-12;
        if !(in_box(alpha[i1] + d1, 0.0, hi[i1]) && in_box(alpha[i2] + d2, 0.0, hi[i2])) {
            let mut best = (f32::INFINITY, 0.0f32, 0.0f32);
            for &(fix1, bound) in &[(true, 0.0f32), (true, hi[i1]), (false, 0.0), (false, hi[i2])]
            {
                let (e1, e2) = if fix1 {
                    let a1 = bound;
                    let dd1 = a1 - alpha[i1];
                    let g2p = g2 + q12 * dd1;
                    let dd2 = ref_clip_step(alpha[i2], g2p, q22, 0.0, hi[i2]);
                    (dd1, dd2)
                } else {
                    let a2 = bound;
                    let dd2 = a2 - alpha[i2];
                    let g1p = g1 + q12 * dd2;
                    let dd1 = ref_clip_step(alpha[i1], g1p, q11, 0.0, hi[i1]);
                    (dd1, dd2)
                };
                let dobj = g1 * e1
                    + g2 * e2
                    + 0.5 * (q11 * e1 * e1 + q22 * e2 * e2)
                    + q12 * e1 * e2;
                if dobj < best.0 {
                    best = (dobj, e1, e2);
                }
            }
            d1 = best.1;
            d2 = best.2;
        }
        alpha[i1] += d1;
        alpha[i2] += d2;
        let yi_d1 = y[i1] * d1;
        let yi_d2 = y[i2] * d2;
        let (mut n1, mut w1) = (usize::MAX, 0.0f32);
        let (mut n2, mut w2) = (usize::MAX, 0.0f32);
        for j in 0..n {
            let gj = g[j] + y[j] * (yi_d1 * k.get(i1, j) + yi_d2 * k.get(i2, j));
            g[j] = gj;
            let v = ref_violation(alpha[j], gj, 0.0, hi[j]);
            if v > w1 {
                n2 = n1;
                w2 = w1;
                n1 = j;
                w1 = v;
            } else if v > w2 {
                n2 = j;
                w2 = v;
            }
        }
        (i1, v1, i2, _v2) = (n1, w1, n2, w2);
        iters += 1;
        pair_steps += 1;
    }
    let obj: f32 = alpha.iter().zip(&g).map(|(&a, &gi)| 0.5 * a * (gi - 1.0)).sum();
    let coef: Vec<f32> = alpha.iter().zip(y).map(|(&a, &yi)| a * yi).collect();
    (coef, obj, 2 * pair_steps + single_steps)
}

/// The pre-engine quantile solver (greedy single-coordinate).
fn ref_quantile(
    k: &Matrix,
    y: &[f32],
    lambda: f32,
    tau: f32,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> (Vec<f32>, f32, usize) {
    let n = y.len();
    let c = ref_box_c(lambda, n);
    let lo = c * (tau - 1.0);
    let hi = c * tau;
    let mut beta: Vec<f32> = match warm {
        Some(prev) => prev.iter().map(|&b| b.clamp(lo, hi)).collect(),
        None => vec![0.0; n],
    };
    let mut g: Vec<f32> = y.iter().map(|&v| -v).collect();
    for j in 0..n {
        if beta[j] != 0.0 {
            let bj = beta[j];
            let krow = k.row(j);
            for i in 0..n {
                g[i] += bj * krow[i];
            }
        }
    }
    let mut best = (usize::MAX, 0.0f32);
    for i in 0..n {
        let v = ref_violation(beta[i], g[i], lo, hi);
        if v > best.1 {
            best = (i, v);
        }
    }
    let mut iters = 0usize;
    while iters < params.max_iter {
        if best.0 == usize::MAX || best.1 <= params.eps {
            break;
        }
        let i = best.0;
        let qii = k.get(i, i).max(1e-12);
        let d = (beta[i] - g[i] / qii).clamp(lo, hi) - beta[i];
        beta[i] += d;
        let krow = k.row(i);
        best = (usize::MAX, 0.0f32);
        for j in 0..n {
            let gj = g[j] + d * krow[j];
            g[j] = gj;
            let v = ref_violation(beta[j], gj, lo, hi);
            if v > best.1 {
                best = (j, v);
            }
        }
        iters += 1;
    }
    let obj: f32 =
        beta.iter().zip(&g).zip(y).map(|((&b, &gi), &yi)| 0.5 * b * gi - 0.5 * yi * b).sum();
    (beta, obj, iters)
}

fn ref_matvec_shifted(k: &Matrix, shift: f32, x: &[f32], out: &mut [f32]) {
    let n = x.len();
    for i in 0..n {
        let row = k.row(i);
        let mut s = 0.0f32;
        for j in 0..n {
            s += row[j] * x[j];
        }
        out[i] = s + shift * x[i];
    }
}

/// The pre-engine least-squares solver (CG on K + nλI).
fn ref_ls(
    k: &Matrix,
    y: &[f32],
    lambda: f32,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> (Vec<f32>, f32, usize) {
    let n = y.len();
    let shift = lambda * n as f32;
    let mut beta: Vec<f32> = warm.map(<[f32]>::to_vec).unwrap_or_else(|| vec![0.0; n]);
    let mut tmp = vec![0.0f32; n];
    ref_matvec_shifted(k, shift, &beta, &mut tmp);
    let mut r: Vec<f32> = y.iter().zip(&tmp).map(|(&a, &b)| a - b).collect();
    let mut p = r.clone();
    let mut rs: f32 = r.iter().map(|v| v * v).sum();
    let y_norm: f32 = y.iter().map(|v| v * v).sum::<f32>().max(1e-12);
    let tol2 = (params.eps * params.eps) * y_norm;
    let mut iters = 0usize;
    let max_cg = params.max_iter.min(4 * n + 50);
    while rs > tol2 && iters < max_cg {
        ref_matvec_shifted(k, shift, &p, &mut tmp);
        let pap: f32 = p.iter().zip(&tmp).map(|(&a, &b)| a * b).sum();
        if pap <= 0.0 {
            break;
        }
        let a = rs / pap;
        for i in 0..n {
            beta[i] += a * p[i];
            r[i] -= a * tmp[i];
        }
        let rs_new: f32 = r.iter().map(|v| v * v).sum();
        let b = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + b * p[i];
        }
        rs = rs_new;
        iters += 1;
    }
    ref_matvec_shifted(k, shift, &beta, &mut tmp);
    let obj: f32 = beta
        .iter()
        .zip(&tmp)
        .zip(y)
        .map(|((&bi, &ti), &yi)| 0.5 * bi * ti - yi * bi)
        .sum();
    (beta, obj, iters)
}

/// The pre-engine expectile solver (cyclic exact piecewise solves).
fn ref_expectile(
    k: &Matrix,
    y: &[f32],
    lambda: f32,
    tau: f32,
    params: &SolverParams,
    warm: Option<&[f32]>,
) -> (Vec<f32>, f32, usize) {
    let n = y.len();
    let c = ref_box_c(lambda, n);
    let mut beta: Vec<f32> = warm.map(<[f32]>::to_vec).unwrap_or_else(|| vec![0.0; n]);
    let mut f = vec![0.0f32; n];
    for j in 0..n {
        if beta[j] != 0.0 {
            let bj = beta[j];
            let krow = k.row(j);
            for i in 0..n {
                f[i] += bj * krow[i];
            }
        }
    }
    let scale: f32 = y.iter().map(|v| v.abs()).fold(0.0, f32::max).max(1.0);
    let mut iters = 0usize;
    let mut sweep_max = f32::INFINITY;
    while sweep_max > params.eps * scale && iters < params.max_iter {
        sweep_max = 0.0;
        for i in 0..n {
            let kii = k.get(i, i).max(1e-12);
            let rest = y[i] - (f[i] - kii * beta[i]);
            let mut new_b = beta[i];
            let bp = 2.0 * c * tau * rest / (1.0 + 2.0 * c * tau * kii);
            if rest - kii * bp >= 0.0 {
                new_b = bp;
            } else {
                let tn = 1.0 - tau;
                let bn = 2.0 * c * tn * rest / (1.0 + 2.0 * c * tn * kii);
                if rest - kii * bn <= 0.0 {
                    new_b = bn;
                }
            }
            let d = new_b - beta[i];
            if d != 0.0 {
                beta[i] = new_b;
                let krow = k.row(i);
                for (j, fj) in f.iter_mut().enumerate() {
                    *fj += d * krow[j];
                }
                sweep_max = sweep_max.max(d.abs() * kii);
            }
            iters += 1;
            if iters >= params.max_iter {
                break;
            }
        }
    }
    let reg: f32 = beta.iter().zip(&f).map(|(&b, &fi)| b * fi).sum();
    let loss: f32 = y
        .iter()
        .zip(&f)
        .map(|(&yi, &fi)| {
            let r = yi - fi;
            if r >= 0.0 { tau * r * r } else { (1.0 - tau) * r * r }
        })
        .sum::<f32>()
        / n as f32;
    (beta, lambda * reg + loss, iters)
}

// ===================================================================
// 1. shrink-off engine ≡ pre-engine reference, bit for bit
// ===================================================================

#[test]
fn engine_hinge_bit_identical_to_reference() {
    let p = off(&SolverParams::default());
    for seed in 0..CASES {
        let d = synth::banana_binary(60 + (seed as usize) * 17, seed);
        let k = gram(&d.x, 1.0 + 0.2 * seed as f32);
        for lambda in [0.05f32, 0.005] {
            let (rc, robj, riters) = ref_hinge(&k, &d.y, lambda, 0.5, &p, None);
            let sol = solve_dense(SolverKind::Hinge { w: 0.5 }, &k, &d.y, lambda, &p, None);
            assert_eq!(bits(&sol.coef), bits(&rc), "seed {seed} λ {lambda}");
            assert_eq!(sol.objective.to_bits(), robj.to_bits(), "seed {seed} λ {lambda}");
            assert_eq!(sol.iterations, riters, "seed {seed} λ {lambda}");
            // warm-started runs must match too (clip + sparse rebuild)
            let warm = warm_vector(SolverKind::Hinge { w: 0.5 }, &sol, &d.y);
            let (rcw, robjw, _) = ref_hinge(&k, &d.y, lambda * 0.7, 0.5, &p, Some(&warm));
            let solw = solve_dense(
                SolverKind::Hinge { w: 0.5 }, &k, &d.y, lambda * 0.7, &p, Some(&warm),
            );
            assert_eq!(bits(&solw.coef), bits(&rcw), "warm seed {seed}");
            assert_eq!(solw.objective.to_bits(), robjw.to_bits(), "warm seed {seed}");
        }
    }
}

#[test]
fn engine_quantile_bit_identical_to_reference() {
    let p = off(&SolverParams::default());
    for seed in 0..CASES {
        let d = synth::sinc_hetero(50 + (seed as usize) * 13, seed);
        let k = gram(&d.x, 0.8);
        for tau in [0.2f32, 0.5, 0.9] {
            let (rc, robj, riters) = ref_quantile(&k, &d.y, 1e-3, tau, &p, None);
            let sol = solve_dense(SolverKind::Quantile { tau }, &k, &d.y, 1e-3, &p, None);
            assert_eq!(bits(&sol.coef), bits(&rc), "seed {seed} tau {tau}");
            assert_eq!(sol.objective.to_bits(), robj.to_bits(), "seed {seed} tau {tau}");
            assert_eq!(sol.iterations, riters, "seed {seed} tau {tau}");
            let (rcw, ..) = ref_quantile(&k, &d.y, 8e-4, tau, &p, Some(&rc));
            let solw =
                solve_dense(SolverKind::Quantile { tau }, &k, &d.y, 8e-4, &p, Some(&sol.coef));
            assert_eq!(bits(&solw.coef), bits(&rcw), "warm seed {seed} tau {tau}");
        }
    }
}

#[test]
fn engine_ls_bit_identical_to_reference() {
    let p = off(&SolverParams { eps: 1e-5, ..Default::default() });
    for seed in 0..CASES {
        let d = synth::sinc_hetero(40 + (seed as usize) * 11, seed ^ 0x55);
        let k = gram(&d.x, 1.2);
        let (rc, robj, rrounds) = ref_ls(&k, &d.y, 1e-3, &p, None);
        let sol = solve_dense(SolverKind::LeastSquares, &k, &d.y, 1e-3, &p, None);
        assert_eq!(bits(&sol.coef), bits(&rc), "seed {seed}");
        assert_eq!(sol.objective.to_bits(), robj.to_bits(), "seed {seed}");
        // the engine reports coordinate updates: rounds · n
        assert_eq!(sol.iterations, rrounds * d.y.len(), "seed {seed}");
        let (rcw, ..) = ref_ls(&k, &d.y, 8e-4, &p, Some(&rc));
        let solw = solve_dense(SolverKind::LeastSquares, &k, &d.y, 8e-4, &p, Some(&sol.coef));
        assert_eq!(bits(&solw.coef), bits(&rcw), "warm seed {seed}");
    }
}

#[test]
fn engine_expectile_bit_identical_to_reference() {
    let p = off(&SolverParams::default());
    for seed in 0..CASES {
        let d = synth::sinc_hetero(45 + (seed as usize) * 9, seed ^ 0xa1);
        let k = gram(&d.x, 0.8);
        for tau in [0.3f32, 0.8] {
            let (rc, robj, riters) = ref_expectile(&k, &d.y, 1e-3, tau, &p, None);
            let sol = solve_dense(SolverKind::Expectile { tau }, &k, &d.y, 1e-3, &p, None);
            assert_eq!(bits(&sol.coef), bits(&rc), "seed {seed} tau {tau}");
            assert_eq!(sol.objective.to_bits(), robj.to_bits(), "seed {seed} tau {tau}");
            assert_eq!(sol.iterations, riters, "seed {seed} tau {tau}");
            let (rcw, ..) = ref_expectile(&k, &d.y, 8e-4, tau, &p, Some(&rc));
            let solw =
                solve_dense(SolverKind::Expectile { tau }, &k, &d.y, 8e-4, &p, Some(&sol.coef));
            assert_eq!(bits(&solw.coef), bits(&rcw), "warm seed {seed} tau {tau}");
        }
    }
}

// ===================================================================
// 2. shrink-on parity: same ε criterion at exit, per loss
// ===================================================================

fn objective_parity(kind: SolverKind, k: &Matrix, y: &[f32], lambda: f32, shrink: usize) {
    let p_off = off(&SolverParams::default());
    let p_on = SolverParams { shrink_every: shrink, ..SolverParams::default() };
    let a = solve_dense(kind, k, y, lambda, &p_off, None);
    let b = solve_dense(kind, k, y, lambda, &p_on, None);
    let tol = 1e-2 * (1.0 + a.objective.abs());
    assert!(
        (a.objective - b.objective).abs() < tol,
        "{kind:?}: shrink-on objective {} vs off {}",
        b.objective,
        a.objective
    );
}

#[test]
fn prop_shrink_parity_all_losses() {
    for seed in 0..CASES {
        let db = synth::banana_binary(120 + (seed as usize) * 19, seed);
        let kb = gram(&db.x, 1.2);
        objective_parity(SolverKind::Hinge { w: 0.5 }, &kb, &db.y, 2e-3, 32);
        let dr = synth::sinc_hetero(110 + (seed as usize) * 15, seed ^ 7);
        let kr = gram(&dr.x, 0.8);
        objective_parity(SolverKind::Quantile { tau: 0.3 }, &kr, &dr.y, 5e-4, 32);
        objective_parity(SolverKind::Expectile { tau: 0.8 }, &kr, &dr.y, 1e-3, 64);
        objective_parity(SolverKind::LeastSquares, &kr, &dr.y, 1e-3, 32);
    }
}

#[test]
fn shrinking_reduces_sweep_work_at_fixed_accuracy() {
    // a problem big enough that shrinking engages well before
    // convergence: many box-pinned coordinates at small λ.
    // `sweep_entries` is the per-solve view of the `solver_sweeps`
    // counter (tests share the process-global counters across
    // threads, so the per-solve field is the race-free measure).
    let d = synth::banana_binary(400, 3);
    let k = gram(&d.x, 1.5);
    let p_off = off(&SolverParams::default());
    let p_on = SolverParams { shrink_every: 200, ..SolverParams::default() };
    let a = solve_dense(SolverKind::Hinge { w: 0.5 }, &k, &d.y, 1e-4, &p_off, None);
    let b = solve_dense(SolverKind::Hinge { w: 0.5 }, &k, &d.y, 1e-4, &p_on, None);
    assert!(
        b.sweep_entries < a.sweep_entries,
        "shrink-on touched {} entries, shrink-off {}",
        b.sweep_entries,
        a.sweep_entries
    );
    let tol = 1e-2 * (1.0 + a.objective.abs());
    assert!((a.objective - b.objective).abs() < tol);
}

// ===================================================================
// 3. the (γ, λ) warm-start plane: γ handoff is never slower
// ===================================================================

fn gamma_handoff(kind: SolverKind, x: &Matrix, y: &[f32], lambdas: &[f32]) {
    let p = SolverParams::default();
    let (g0, g1) = (1.1f32, 1.0f32);
    let k0 = gram(x, g0);
    let k1 = gram(x, g1);
    // walk γ0's λ chain to its terminal solution
    let mut warm: Option<Vec<f32>> = None;
    for &l in lambdas {
        let sol = solve_dense(kind, &k0, y, l, &p, warm.as_deref());
        warm = Some(warm_vector(kind, &sol, y));
    }
    // γ1's first λ: handoff vs cold
    let warm_run = solve_dense(kind, &k1, y, lambdas[0], &p, warm.as_deref());
    let cold_run = solve_dense(kind, &k1, y, lambdas[0], &p, None);
    assert!(
        warm_run.iterations <= cold_run.iterations,
        "{kind:?}: γ-handoff took {} iterations, cold {}",
        warm_run.iterations,
        cold_run.iterations
    );
    let tol = 1e-2 * (1.0 + cold_run.objective.abs());
    assert!((warm_run.objective - cold_run.objective).abs() < tol, "{kind:?} objective drift");
}

#[test]
fn warm_across_gamma_no_slower_than_cold_all_losses() {
    let db = synth::banana_binary(180, 11);
    let lam_cls = [2e-3f32, 1e-3, 5e-4];
    gamma_handoff(SolverKind::Hinge { w: 0.5 }, &db.x, &db.y, &lam_cls);
    let dr = synth::sinc_hetero(150, 12);
    let lam_reg = [2e-3f32, 1e-3, 5e-4];
    gamma_handoff(SolverKind::LeastSquares, &dr.x, &dr.y, &lam_reg);
    gamma_handoff(SolverKind::Quantile { tau: 0.5 }, &dr.x, &dr.y, &lam_reg);
    gamma_handoff(SolverKind::Expectile { tau: 0.5 }, &dr.x, &dr.y, &lam_reg);
}

// ===================================================================
// CV-level: selection/test-error parity and jobs-independence with
// shrinking on
// ===================================================================

use liquid_svm::cv::{run_cv, predict_average, CvConfig, Grid};
use liquid_svm::metrics::Loss;

fn cv_cfg(n_fold: usize, shrink_every: usize) -> CvConfig {
    let mut cfg = CvConfig::new(
        Grid::default_grid(0, n_fold, 2),
        SolverKind::Hinge { w: 0.5 },
        Loss::Classification,
    );
    cfg.folds = 3;
    cfg.params = SolverParams { shrink_every, ..SolverParams::default() };
    cfg
}

#[test]
fn cv_shrink_parity_selection_and_test_error() {
    let d = synth::banana_binary(240, 21);
    let test = synth::banana_binary(150, 22);
    let cfg_off = cv_cfg(160, 0);
    let cfg_on = cv_cfg(160, 64);
    let a = run_cv(&d, &cfg_off);
    let b = run_cv(&d, &cfg_on);
    assert_eq!(a.best_gamma.to_bits(), b.best_gamma.to_bits(), "γ* changed under shrinking");
    assert_eq!(a.best_lambda.to_bits(), b.best_lambda.to_bits(), "λ* changed under shrinking");
    let pa = predict_average(
        &a.models, &d, &test.x, a.best_gamma, cfg_off.kernel, &cfg_off.backend, None,
    );
    let pb = predict_average(
        &b.models, &d, &test.x, b.best_gamma, cfg_on.kernel, &cfg_on.backend, None,
    );
    let ea = Loss::Classification.mean(&test.y, &pa);
    let eb = Loss::Classification.mean(&test.y, &pb);
    assert!(
        (ea - eb).abs() < 0.02 + 1e-6,
        "test error moved under shrinking: {ea} vs {eb}"
    );
}

#[test]
fn cv_shrink_parity_quantile_selection() {
    use liquid_svm::data::folds::FoldKind;
    let d = synth::sinc_hetero(180, 31);
    let mut cfg_off = CvConfig::new(
        Grid::default_grid(0, 120, 1),
        SolverKind::Quantile { tau: 0.5 },
        Loss::Pinball { tau: 0.5 },
    );
    cfg_off.folds = 3;
    cfg_off.fold_kind = FoldKind::Random;
    cfg_off.params = SolverParams { shrink_every: 0, ..SolverParams::default() };
    let mut cfg_on = cfg_off.clone();
    cfg_on.params = SolverParams { shrink_every: 48, ..SolverParams::default() };
    let a = run_cv(&d, &cfg_off);
    let b = run_cv(&d, &cfg_on);
    assert_eq!(a.best_gamma.to_bits(), b.best_gamma.to_bits());
    assert_eq!(a.best_lambda.to_bits(), b.best_lambda.to_bits());
    assert!(
        (a.best_val_loss - b.best_val_loss).abs() < 1e-2 * (1.0 + a.best_val_loss.abs()),
        "val loss moved under shrinking: {} vs {}",
        a.best_val_loss,
        b.best_val_loss
    );
}

#[test]
fn cv_shrink_on_jobs_independent() {
    let d = synth::banana_binary(180, 23);
    let mut seq = cv_cfg(120, 48);
    seq.jobs = 1;
    let mut par = cv_cfg(120, 48);
    par.jobs = 4;
    let a = run_cv(&d, &seq);
    let b = run_cv(&d, &par);
    assert_eq!(a.best_gamma.to_bits(), b.best_gamma.to_bits());
    assert_eq!(a.best_lambda.to_bits(), b.best_lambda.to_bits());
    for (ra, rb) in a.val_matrix.iter().zip(&b.val_matrix) {
        for (va, vb) in ra.iter().zip(rb) {
            assert!(
                va.to_bits() == vb.to_bits() || (va.is_nan() && vb.is_nan()),
                "val {va} vs {vb}"
            );
        }
    }
    for (ma, mb) in a.models.iter().zip(&b.models) {
        assert_eq!(ma.train_idx, mb.train_idx);
        assert_eq!(bits(&ma.coef), bits(&mb.coef));
    }
}
