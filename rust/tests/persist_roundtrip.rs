//! Round-trip property tests for `coordinator/persist`: serving
//! correctness rests on `save_model` → `load_model` reproducing the
//! exact solution, so for each scenario family (mc/ls/qt) we train a
//! tiny model, round-trip it through a `.sol` file, and assert that
//! decision values AND combined predictions are bit-identical on a
//! held-out evaluation grid that covers the input domain (not just the
//! training distribution — padding/extrapolation paths included).

use liquid_svm::coordinator::persist::{load_model, save_model};
use liquid_svm::coordinator::SvmModel;
use liquid_svm::data::matrix::Matrix;
use liquid_svm::data::synth;
use liquid_svm::prelude::*;

/// Held-out evaluation grid: a lattice over `[-lim, lim]^dim`
/// (dim ≤ 2 here; the synth scenario sets are 1-d and 2-d).
fn eval_grid(dim: usize, lim: f32, steps: usize) -> Matrix {
    assert!(dim == 1 || dim == 2);
    let lin = |k: usize| -lim + 2.0 * lim * (k as f32) / (steps - 1) as f32;
    if dim == 1 {
        let data: Vec<f32> = (0..steps).map(lin).collect();
        Matrix::from_vec(data, steps, 1)
    } else {
        let mut data = Vec::with_capacity(steps * steps * 2);
        for i in 0..steps {
            for j in 0..steps {
                data.push(lin(i));
                data.push(lin(j));
            }
        }
        Matrix::from_vec(data, steps * steps, 2)
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("lsvm-roundtrip-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn assert_roundtrip(model: &SvmModel, cfg: &Config, file: &str, grid: &Matrix) {
    let path = tmp(file);
    save_model(model, &path).unwrap();
    let back = load_model(&path, cfg).unwrap();
    assert_eq!(back.n_tasks, model.n_tasks, "{file}: task count");
    assert_eq!(
        back.decision_values(grid),
        model.decision_values(grid),
        "{file}: decision values diverged after reload"
    );
    assert_eq!(
        back.predict(grid),
        model.predict(grid),
        "{file}: combined predictions diverged after reload"
    );
}

#[test]
fn mc_models_roundtrip_on_grid_across_seeds() {
    let grid = eval_grid(2, 3.5, 13);
    for seed in [1u64, 2, 3] {
        let tt = synth::banana_mc(160, 10, seed);
        let cfg = Config::default().folds(2).seed(seed);
        let m = mc_svm(&tt.train, &cfg).unwrap();
        assert_roundtrip(&m, &cfg, &format!("mc-{seed}.sol"), &grid);
    }
}

#[test]
fn ls_models_roundtrip_on_grid_across_seeds() {
    let grid = eval_grid(1, 3.5, 101);
    for seed in [4u64, 5, 6] {
        let d = synth::sinc_hetero(120, seed);
        let cfg = Config::default().folds(2).seed(seed);
        let m = ls_svm(&d, &cfg).unwrap();
        assert_roundtrip(&m, &cfg, &format!("ls-{seed}.sol"), &grid);
    }
}

#[test]
fn qt_models_roundtrip_on_grid_across_seeds() {
    let grid = eval_grid(1, 3.5, 101);
    for seed in [7u64, 8] {
        let d = synth::sinc_hetero(120, seed);
        let cfg = Config::default().folds(2).seed(seed);
        let m = qt_svm(&d, &[0.1, 0.5, 0.9], &cfg).unwrap();
        assert_roundtrip(&m, &cfg, &format!("qt-{seed}.sol"), &grid);
    }
}

#[test]
fn roundtrip_survives_a_second_generation() {
    // save → load → save → load must be a fixed point
    let tt = synth::banana_mc(140, 10, 11);
    let cfg = Config::default().folds(2);
    let m = mc_svm(&tt.train, &cfg).unwrap();
    let p1 = tmp("gen1.sol");
    let p2 = tmp("gen2.sol");
    save_model(&m, &p1).unwrap();
    let g1 = load_model(&p1, &cfg).unwrap();
    save_model(&g1, &p2).unwrap();
    let g2 = load_model(&p2, &cfg).unwrap();
    let grid = eval_grid(2, 3.0, 9);
    assert_eq!(g1.predict(&grid), g2.predict(&grid));
    assert_eq!(
        std::fs::read_to_string(&p1).unwrap(),
        std::fs::read_to_string(&p2).unwrap(),
        "serialization is not canonical across generations"
    );
}
