//! End-to-end tests of the `serve` subsystem over real TCP: a model
//! trained in-process is saved, loaded over the wire, and queried by
//! concurrent clients whose answers must match direct `predict` calls.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use liquid_svm::coordinator::persist::save_model;
use liquid_svm::data::synth;
use liquid_svm::prelude::*;
use liquid_svm::serve::{run_load, LoadSpec, ServeConfig, Server};

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn roundtrip(&mut self, req: &str) -> String {
        writeln!(self.writer, "{req}").unwrap();
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("lsvm-serve-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn start_server(max_batch: usize, max_delay_ms: u64) -> Server {
    Server::start(ServeConfig {
        port: 0,
        max_batch,
        max_delay: Duration::from_millis(max_delay_ms),
        workers: 2,
        ..ServeConfig::default()
    })
    .unwrap()
}

#[test]
fn protocol_end_to_end() {
    let d = synth::banana_binary(150, 31);
    let cfg = Config::default().folds(2);
    let model = svm_binary(&d, 0.5, &cfg).unwrap();
    let sol = tmp("proto.sol");
    save_model(&model, &sol).unwrap();

    let server = start_server(8, 1);
    let mut c = Client::connect(server.addr());

    assert_eq!(c.roundtrip("ping"), "ok pong");
    assert!(c.roundtrip("predict nope 1,2").starts_with("err unknown-model"));
    assert!(c.roundtrip("garbage").starts_with("err bad-request"));

    let loaded = c.roundtrip(&format!("load banana {}", sol.display()));
    assert!(loaded.starts_with("ok loaded banana dim=2"), "{loaded}");

    // single-row predictions match in-process predict exactly
    let test = synth::banana_binary(20, 32);
    let expect = model.predict(&test.x);
    for i in 0..test.len() {
        let row = test.x.row(i);
        let resp = c.roundtrip(&format!("predict banana {},{}", row[0], row[1]));
        let body = resp.strip_prefix("ok ").unwrap_or_else(|| panic!("bad resp {resp}"));
        assert_eq!(body.parse::<f32>().unwrap(), expect[i], "row {i}");
    }

    // multi-row request answers all rows in order
    let resp = c.roundtrip(&format!(
        "predict banana {},{};{},{}",
        test.x.get(0, 0),
        test.x.get(0, 1),
        test.x.get(1, 0),
        test.x.get(1, 1)
    ));
    let vals: Vec<f32> = resp
        .strip_prefix("ok ")
        .unwrap()
        .split(';')
        .map(|t| t.parse().unwrap())
        .collect();
    assert_eq!(vals, vec![expect[0], expect[1]]);

    assert!(c.roundtrip("predict banana 1,2,3").starts_with("err dim-mismatch"));

    let stats = c.roundtrip("stats");
    assert!(stats.starts_with("ok models=1 uptime_s="), "{stats}");
    assert!(stats.contains("p99_us="), "{stats}");
    assert!(stats.contains("gram_hits="), "{stats}");
    assert!(stats.contains("model_rows=banana:"), "{stats}");

    assert_eq!(c.roundtrip("unload banana"), "ok unloaded banana");
    assert!(c.roundtrip("predict banana 1,2").starts_with("err unknown-model"));
    assert_eq!(c.roundtrip("quit"), "ok bye");

    server.shutdown();
}

#[test]
fn thousand_concurrent_requests_all_answered_correctly() {
    // the acceptance demo: ≥1000 concurrent requests, every answer
    // identical to the in-process model
    let d = synth::banana_binary(200, 33);
    let cfg = Config::default().folds(2);
    let model = svm_binary(&d, 0.5, &cfg).unwrap();

    let server = start_server(32, 1);
    server.registry.insert("banana", model);
    let served = server.registry.get("banana").unwrap();

    let test = synth::banana_binary(250, 34);
    let rows: Vec<Vec<f32>> = (0..test.len()).map(|i| test.x.row(i).to_vec()).collect();
    let expected = served.model.predict(&test.x);

    let report = run_load(
        &LoadSpec {
            addr: server.addr().to_string(),
            model: "banana".into(),
            connections: 8,
            requests: 125,
            pipeline: 25,
        },
        &rows,
        Some(&expected),
    )
    .unwrap();

    assert_eq!(report.ok, 1000, "{}", report.report());
    assert_eq!(report.mismatches, 0, "{}", report.report());
    assert_eq!(report.failed, 0, "{}", report.report());

    // batching actually happened: far fewer fused calls than rows
    let batches = server.stats.batches.get();
    let rows_served = server.stats.batched_rows.get();
    assert!(rows_served >= 1000, "rows_served={rows_served}");
    assert!(
        batches < rows_served / 2,
        "no batching: {batches} batches for {rows_served} rows"
    );
    server.shutdown();
}

#[test]
fn backpressure_surfaces_as_busy_and_clients_recover() {
    // a deliberately strangled server: 1-row batches, 1-batch queue,
    // a single worker — concurrent load must hit `err busy` yet every
    // request eventually completes via client retry
    let d = synth::banana_binary(120, 35);
    let model = svm_binary(&d, 0.5, &Config::default().folds(2)).unwrap();
    let server = Server::start(ServeConfig {
        port: 0,
        max_batch: 1,
        max_delay: Duration::from_millis(1),
        queue_cap: 1,
        workers: 1,
        ..ServeConfig::default()
    })
    .unwrap();
    server.registry.insert("m", model);

    let test = synth::banana_binary(40, 36);
    let rows: Vec<Vec<f32>> = (0..test.len()).map(|i| test.x.row(i).to_vec()).collect();
    let report = run_load(
        &LoadSpec {
            addr: server.addr().to_string(),
            model: "m".into(),
            connections: 4,
            requests: 50,
            pipeline: 10,
        },
        &rows,
        None,
    )
    .unwrap();
    assert_eq!(report.ok, 200, "{}", report.report());
    assert_eq!(report.failed, 0, "{}", report.report());
    // with cap 1 and 4 connections something must have bounced
    assert!(report.rejected > 0, "expected busy responses: {}", report.report());
    assert_eq!(server.stats.rejected.get(), report.rejected as u64);
    server.shutdown();
}

#[test]
fn hot_reload_swaps_model_between_requests() {
    // regression models: continuous outputs, so the two generations
    // actually produce distinguishable predictions
    let cfg = Config::default().folds(2);
    let m1 = ls_svm(&synth::sinc_hetero(80, 37), &cfg).unwrap();
    let m2 = ls_svm(&synth::sinc_hetero(150, 38), &cfg).unwrap();
    let sol = tmp("hot.sol");
    save_model(&m1, &sol).unwrap();

    let server = start_server(8, 1);
    let mut c = Client::connect(server.addr());
    assert!(c.roundtrip(&format!("load m {}", sol.display())).starts_with("ok"));

    let test = synth::sinc_hetero(10, 39);
    let (e1, e2) = (m1.predict(&test.x), m2.predict(&test.x));
    let row = format!("{}", test.x.get(0, 0));
    let r = c.roundtrip(&format!("predict m {row}"));
    assert_eq!(r, format!("ok {}", e1[0]));

    save_model(&m2, &sol).unwrap(); // overwrite on disk
    let r = c.roundtrip(&format!("predict m {row}"));
    assert_eq!(r, format!("ok {}", e2[0]), "server kept serving the stale model");

    server.shutdown();
}
