//! End-to-end tests of the sparse data plane: CSR training against the
//! densified twin (bit-identity), persistence round-trips, the
//! high-dimensional memory profile, and sparse `idx:val` rows over the
//! serve wire protocol.

use liquid_svm::cells::CellStrategy;
use liquid_svm::coordinator::persist::{load_model, save_bundle, save_model};
use liquid_svm::coordinator::train_sparse;
use liquid_svm::data::synth;
use liquid_svm::prelude::*;
use liquid_svm::tasks::TaskSpec;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("lsvm-sparse-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// A config both pipelines can run identically: no scaling (the sparse
/// path's boundary), single cell, small fold count.
fn flat_cfg() -> Config {
    let mut cfg = Config::default().folds(3);
    cfg.scale = None;
    cfg
}

#[test]
fn sparse_train_bit_identical_to_densified_train() {
    let train = synth::sparse_binary(140, 300, 0.02, 5);
    let test = synth::sparse_binary(60, 300, 0.02, 6);
    let cfg = flat_cfg();
    let spec = TaskSpec::Binary { w: 0.5 };

    let sparse_model = train_sparse(&train, &spec, &cfg).unwrap();
    let dense_model = liquid_svm::coordinator::train(&train.to_dense(), &spec, &cfg).unwrap();

    // identical hyper-parameter selection...
    assert_eq!(sparse_model.selected_params(), dense_model.selected_params());

    // ...and bitwise-identical predictions, sparse input vs densified
    let sp = sparse_model.test_sparse(&test);
    let dp = dense_model.test(&test.to_dense());
    assert_eq!(sp.predictions, dp.predictions);
    for (a, b) in sp.task_scores.iter().zip(&dp.task_scores) {
        let bits_a: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
        let bits_b: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits_a, bits_b, "decision values diverged");
    }
    assert_eq!(sp.error, dp.error);

    // the sparse model also answers dense queries identically (dense
    // rows sparsify at the tile boundary)
    let dense_x = test.to_dense().x;
    let via_dense = sparse_model.predict(&dense_x);
    let via_sparse = sparse_model.predict_csr(&test.x);
    assert_eq!(via_dense, via_sparse);
}

#[test]
fn sparse_memory_tiers_agree_end_to_end() {
    // the coordinator clamps per-unit budgets to ≥ 1 MiB, so the
    // forced-streamed case lives in cv's unit tests
    // (`sparse_cv_bit_identical_to_densified` runs Some(0)); here the
    // capped and unlimited coordinator paths must agree bitwise
    let train = synth::sparse_binary(90, 150, 0.03, 7);
    let test = synth::sparse_binary(40, 150, 0.03, 8);
    let spec = TaskSpec::Binary { w: 0.5 };
    let unlimited = flat_cfg().max_gram_mb(0);
    let capped = flat_cfg().max_gram_mb(1);
    let a = train_sparse(&train, &spec, &unlimited).unwrap().test_sparse(&test);
    let b = train_sparse(&train, &spec, &capped).unwrap().test_sparse(&test);
    assert_eq!(a.predictions, b.predictions);
}

#[test]
fn sparse_multiclass_and_regression_scenarios_run() {
    // all four solver families through the sparse plane
    let mut d = synth::sparse_binary(120, 80, 0.05, 11);
    // relabel into 3 classes for the OvA path
    for (i, y) in d.y.iter_mut().enumerate() {
        *y = (i % 3) as f32;
    }
    let cfg = flat_cfg();
    let m = train_sparse(&d, &TaskSpec::MultiClassOvA, &cfg).unwrap();
    assert_eq!(m.n_tasks, 3);
    let preds = m.predict_csr(&d.x);
    assert!(preds.iter().all(|&p| (0.0..3.0).contains(&p)));

    let mut reg = synth::sparse_binary(100, 60, 0.05, 12);
    for (i, y) in reg.y.iter_mut().enumerate() {
        *y = (i as f32 * 0.01).sin();
    }
    for spec in [
        TaskSpec::LeastSquares,
        TaskSpec::MultiQuantile { taus: vec![0.5] },
        TaskSpec::MultiExpectile { taus: vec![0.5] },
    ] {
        let m = train_sparse(&reg, &spec, &cfg).unwrap();
        let res = m.test_sparse(&reg);
        assert!(res.error.is_finite(), "{spec:?}");
    }
}

#[test]
fn sparse_chunked_cells_supported_geometric_rejected() {
    let d = synth::sparse_binary(160, 90, 0.05, 13);
    let mut cfg = flat_cfg();
    cfg.cells = CellStrategy::RandomChunks { size: 50 };
    let m = train_sparse(&d, &TaskSpec::Binary { w: 0.5 }, &cfg).unwrap();
    assert!(m.partition.n_cells() > 1);
    assert_eq!(m.predict_csr(&d.x).len(), 160);

    cfg.cells = CellStrategy::Voronoi { size: 50 };
    let err = train_sparse(&d, &TaskSpec::Binary { w: 0.5 }, &cfg).unwrap_err();
    assert!(format!("{err:#}").contains("dense geometry"), "{err:#}");
}

#[test]
fn sparse_model_persist_roundtrip_sol_and_bundle() {
    let train = synth::sparse_binary(120, 250, 0.02, 21);
    let test = synth::sparse_binary(50, 250, 0.02, 22);
    let cfg = flat_cfg();
    let m = train_sparse(&train, &TaskSpec::Binary { w: 0.5 }, &cfg).unwrap();
    let expect = m.predict_csr(&test.x);

    let sol = tmp("sparse.sol");
    save_model(&m, &sol).unwrap();
    let back = load_model(&sol, &cfg).unwrap();
    // the reloaded working sets stay CSR (no densification on disk)
    assert!(back.units.iter().all(|u| u.data.x.is_sparse()));
    assert_eq!(back.predict_csr(&test.x), expect);

    let dir = tmp("sparse.sol.d");
    save_bundle(&m, &dir).unwrap();
    let back = load_model(&dir, &cfg).unwrap();
    assert_eq!(back.predict_csr(&test.x), expect);
}

#[test]
fn high_dim_sparse_trains_where_dense_bytes_explode() {
    // the acceptance shape: d = 50 000 at 0.05% density.  The CSR
    // triplet holds ~25 nnz/row; the dense twin would need n·d floats
    // — 200× more than the entire sparse footprint here.  Train +
    // predict end-to-end under a finite Gram budget, no densification.
    let (n, d) = (160usize, 50_000usize);
    let train = synth::sparse_binary(n, d, 0.0005, 31);
    let test = synth::sparse_binary(60, d, 0.0005, 32);
    let dense_bytes = n * d * 4;
    assert!(
        train.x.bytes() * 100 < dense_bytes,
        "CSR {} vs dense {} bytes",
        train.x.bytes(),
        dense_bytes
    );
    let mut cfg = flat_cfg();
    cfg = cfg.folds(2).max_gram_mb(64);
    let m = train_sparse(&train, &TaskSpec::Binary { w: 0.5 }, &cfg).unwrap();
    let res = m.test_sparse(&test);
    assert_eq!(res.predictions.len(), 60);
    assert!(res.error.is_finite());
}

#[test]
fn serve_answers_sparse_rows() {
    use liquid_svm::serve::{ServeConfig, Server};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let train = synth::sparse_binary(120, 40, 0.1, 41);
    let cfg = flat_cfg();
    let m = train_sparse(&train, &TaskSpec::Binary { w: 0.5 }, &cfg).unwrap();
    let sol = tmp("serve-sparse.sol");
    save_model(&m, &sol).unwrap();

    let server = Server::start(ServeConfig {
        port: 0,
        max_delay: std::time::Duration::from_millis(1),
        ..ServeConfig::default()
    })
    .unwrap();
    let stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut roundtrip = |req: &str| -> String {
        writeln!(writer, "{req}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    };

    let loaded = roundtrip(&format!("load sp {}", sol.display()));
    assert!(loaded.starts_with("ok loaded sp dim=40"), "{loaded}");

    // sparse wire rows answer exactly like predict_csr
    let expect = m.predict_csr(&train.x);
    for i in 0..8 {
        let (idx, val) = train.x.row(i);
        let toks: Vec<String> =
            idx.iter().zip(val).map(|(&j, &v)| format!("{}:{}", j + 1, v)).collect();
        let resp = roundtrip(&format!("predict sp {}", toks.join(",")));
        let body = resp.strip_prefix("ok ").unwrap_or_else(|| panic!("bad resp {resp}"));
        assert_eq!(body.parse::<f32>().unwrap(), expect[i], "row {i}");
    }

    // an index past the model dim fails the row, not the server
    let resp = roundtrip("predict sp 99:1");
    assert!(resp.starts_with("err dim-mismatch"), "{resp}");
    assert_eq!(roundtrip("ping"), "ok pong");
    server.shutdown();
}
