//! Tables 14–17 reproduction: the vectorization ladder of the Gram
//! hot spot.  The paper compares SSE2 / AVX / AVX2 compile targets;
//! this port's equivalent rungs are
//!
//!   scalar   — naive per-pair loops            (paper's SSE2 column)
//!   blocked  — norm-trick + unrolled dots      (paper's AVX/AVX2)
//!   simd     — explicit std::arch kernels behind the runtime
//!              dispatch seam (DESIGN.md §Compute-plane), plus the
//!              opt-in f32 mixed-precision fill
//!   xla      — AOT Pallas/XLA artifact (PJRT)  (the accelerator rung)
//!
//! Measured three ways: the raw multi-γ Gram kernel (10 γ, the CV hot
//! spot), a dimension sweep of the per-rung distance fill (where the
//! SIMD win scales with d), and a full small training run per backend.
//!
//! Paper shape: each rung up is faster; the gap grows with dimension
//! (d=8 barely moves, d=54/254 clearly does).

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;

use harness::{scale, sized, time_median, time_once, Scale, Snapshot, Table};
use liquid_svm::coordinator::config::BackendChoice;
use liquid_svm::data::matrix::Matrix;
use liquid_svm::data::rng::Rng;
use liquid_svm::data::synth;
use liquid_svm::kernel::simd;
use liquid_svm::kernel::{GramBackend, KernelKind, SimdLevel, SimdPlan};
use liquid_svm::prelude::*;
use liquid_svm::runtime::{default_artifact_dir, XlaRuntime};

fn main() {
    let n = sized(256, 1000, 2000);
    println!("\n=== Tables 14-17: Gram backend ladder (n={n}, 10 gammas) ===\n");

    let xla = XlaRuntime::open(default_artifact_dir()).ok().map(Arc::new);
    if xla.is_none() {
        println!("(artifacts missing — run `make artifacts` to include the xla rung)\n");
    }

    let gammas: Vec<f32> = (1..=10).map(|i| 0.3 * i as f32).collect();
    let mut snap = Snapshot::new("table14_simd");
    let t = Table::new(
        &["dataset", "dim", "scalar", "blocked", "xla", "blocked-speedup", "xla-speedup"],
        &[14, 5, 9, 9, 9, 16, 12],
    );

    for name in ["cod-rna", "thyroid-ann", "covtype", "webspam"] {
        let d = synth::by_name(name, n, 9).unwrap();
        let reps = if n <= 300 { 3 } else { 2 };
        let t_scalar =
            time_median(reps, || GramBackend::Scalar.gram_multi(&d.x, &d.x, &gammas, KernelKind::Gauss));
        let t_blocked =
            time_median(reps, || GramBackend::Blocked.gram_multi(&d.x, &d.x, &gammas, KernelKind::Gauss));
        let (t_xla_str, xla_speed) = match &xla {
            Some(rt) => {
                let be = GramBackend::Xla(rt.clone());
                // warm the executable cache, then measure
                let _ = be.gram_multi(&d.x, &d.x, &gammas, KernelKind::Gauss);
                let t_xla = time_median(reps, || be.gram_multi(&d.x, &d.x, &gammas, KernelKind::Gauss));
                (
                    format!("{:.3}s", t_xla.as_secs_f64()),
                    format!("x{:.1}", t_scalar.as_secs_f64() / t_xla.as_secs_f64().max(1e-9)),
                )
            }
            None => ("-".into(), "-".into()),
        };
        t.row(&[
            name,
            &d.dim().to_string(),
            &format!("{:.3}s", t_scalar.as_secs_f64()),
            &format!("{:.3}s", t_blocked.as_secs_f64()),
            &t_xla_str,
            &format!("x{:.1}", t_scalar.as_secs_f64() / t_blocked.as_secs_f64().max(1e-9)),
            &xla_speed,
        ]);
        // 10 γ surfaces of n×n entries per gram_multi call
        let entries = (n * n * gammas.len()) as f64;
        snap.case(
            &format!("{name}_scalar"),
            t_scalar,
            entries / t_scalar.as_secs_f64().max(1e-9),
            "entries/s",
        );
        snap.case(
            &format!("{name}_blocked"),
            t_blocked,
            entries / t_blocked.as_secs_f64().max(1e-9),
            "entries/s",
        );
    }

    // rung sweep: the per-pair distance fill itself, across the full
    // dispatch ladder and the dimensions where SIMD starts to pay.
    // (d=8 fits in one lane-group — overhead territory; by d=64 the
    // vector rungs should clearly win; d=4096 is the wide-feature
    // regime of the paper's Tables 16-17.)
    let sweep_n = sized(160, 384, 768);
    let detected = simd::detect();
    println!(
        "\n--- distance-fill rung sweep (n={sweep_n}, detected rung: {}) ---\n",
        detected.name()
    );
    let mut rungs: Vec<(String, GramBackend)> = vec![
        ("scalar".into(), GramBackend::Scalar),
        ("blocked".into(), GramBackend::Blocked),
    ];
    for level in simd::available() {
        rungs.push((
            format!("simd-{}", level.name()),
            GramBackend::Simd(SimdPlan::forced(level, false)),
        ));
    }
    rungs.push((
        format!("simd-{}-f32", detected.name()),
        GramBackend::Simd(SimdPlan::forced(detected, true)),
    ));
    let headers: Vec<&str> =
        std::iter::once("dim").chain(rungs.iter().map(|(l, _)| l.as_str())).collect();
    let widths: Vec<usize> = std::iter::once(5).chain(rungs.iter().map(|_| 14)).collect();
    let t_sweep = Table::new(&headers, &widths);
    let mut sweep_times: Vec<(usize, String, std::time::Duration)> = Vec::new();
    for d in [8usize, 64, 512, 4096] {
        let mut rng = Rng::new(d as u64);
        let x = Matrix::from_vec(
            (0..sweep_n * d).map(|_| rng.range(-2.0, 2.0)).collect(),
            sweep_n,
            d,
        );
        let entries = (sweep_n * sweep_n) as f64;
        let reps = if d >= 512 { 2 } else { 3 };
        let mut cells: Vec<String> = vec![d.to_string()];
        for (label, be) in &rungs {
            let dt = time_median(reps, || be.sq_dists(&x, &x));
            let eps = entries / dt.as_secs_f64().max(1e-9);
            cells.push(format!("{:.1}M/s", eps / 1e6));
            snap.case(&format!("d{d}_{label}"), dt, eps, "entries/s");
            sweep_times.push((d, label.clone(), dt));
        }
        let refs: Vec<&str> = cells.iter().map(|s| s.as_str()).collect();
        t_sweep.row(&refs);
    }
    // smoke-mode regression gate: the auto-detected SIMD rung must not
    // lose to blocked once the dimension amortizes dispatch (d ≥ 64).
    // On machines where detection lands on the portable level there is
    // no vector rung to gate — skip loudly rather than assert noise.
    if scale() == Scale::Smoke {
        if detected == SimdLevel::Portable {
            println!("\n(no vector rung detected — skipping simd≥blocked assertion)");
        } else {
            let auto_label = format!("simd-{}", detected.name());
            for d in [64usize, 512, 4096] {
                let of = |l: &str| {
                    sweep_times
                        .iter()
                        .find(|(sd, sl, _)| *sd == d && sl == l)
                        .map(|(_, _, t)| t.as_secs_f64())
                        .unwrap()
                };
                let (t_simd, t_blocked) = (of(&auto_label), of("blocked"));
                assert!(
                    t_simd <= t_blocked * 1.10,
                    "simd rung slower than blocked at d={d}: {t_simd:.4}s vs {t_blocked:.4}s"
                );
            }
            println!("\n(smoke gate: {auto_label} ≥ blocked at d ≥ 64 — ok)");
        }
    }

    // end-to-end: full training run per backend on one dataset
    println!("\n--- end-to-end training, covtype n={} ---\n", n.min(1000));
    let train = synth::by_name("covtype", n.min(1000), 10).unwrap();
    let t2 = Table::new(&["backend", "train time", "error"], &[10, 11, 8]);
    for (label, be) in [
        ("scalar", BackendChoice::Scalar),
        ("blocked", BackendChoice::Blocked),
        ("simd", BackendChoice::Simd),
        ("simd-f32", BackendChoice::SimdF32),
        ("xla", BackendChoice::Xla),
    ] {
        if be == BackendChoice::Xla && xla.is_none() {
            continue;
        }
        let cfg = Config::default().folds(3).backend(be);
        let (m, dt) = time_once(|| svm_binary(&train, 0.5, &cfg).unwrap());
        let test = synth::by_name("covtype", 500, 11).unwrap();
        t2.row(&[
            label,
            &format!("{:.2}s", dt.as_secs_f64()),
            &format!("{:.3}", m.test(&test).error),
        ]);
        snap.case(
            &format!("train_covtype_{label}"),
            dt,
            train.len() as f64 / dt.as_secs_f64().max(1e-9),
            "rows/s",
        );
    }
    snap.write();
    println!("\npaper shape: each vectorization rung up is faster, gap grows with dim.");
}
