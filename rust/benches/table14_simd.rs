//! Tables 14–17 reproduction: the vectorization ladder of the Gram
//! hot spot.  The paper compares SSE2 / AVX / AVX2 compile targets;
//! this port's equivalent rungs are
//!
//!   scalar   — naive per-pair loops            (paper's SSE2 column)
//!   blocked  — norm-trick + unrolled dots      (paper's AVX/AVX2)
//!   xla      — AOT Pallas/XLA artifact (PJRT)  (the accelerator rung)
//!
//! Measured two ways: the raw multi-γ Gram kernel (10 γ, the CV hot
//! spot) and a full small training run per backend.
//!
//! Paper shape: each rung up is faster; the gap grows with dimension
//! (d=8 barely moves, d=54/254 clearly does).

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;

use harness::{sized, time_median, time_once, Snapshot, Table};
use liquid_svm::coordinator::config::BackendChoice;
use liquid_svm::data::synth;
use liquid_svm::kernel::{GramBackend, KernelKind};
use liquid_svm::prelude::*;
use liquid_svm::runtime::{default_artifact_dir, XlaRuntime};

fn main() {
    let n = sized(256, 1000, 2000);
    println!("\n=== Tables 14-17: Gram backend ladder (n={n}, 10 gammas) ===\n");

    let xla = XlaRuntime::open(default_artifact_dir()).ok().map(Arc::new);
    if xla.is_none() {
        println!("(artifacts missing — run `make artifacts` to include the xla rung)\n");
    }

    let gammas: Vec<f32> = (1..=10).map(|i| 0.3 * i as f32).collect();
    let mut snap = Snapshot::new("table14_simd");
    let t = Table::new(
        &["dataset", "dim", "scalar", "blocked", "xla", "blocked-speedup", "xla-speedup"],
        &[14, 5, 9, 9, 9, 16, 12],
    );

    for name in ["cod-rna", "thyroid-ann", "covtype", "webspam"] {
        let d = synth::by_name(name, n, 9).unwrap();
        let reps = if n <= 300 { 3 } else { 2 };
        let t_scalar =
            time_median(reps, || GramBackend::Scalar.gram_multi(&d.x, &d.x, &gammas, KernelKind::Gauss));
        let t_blocked =
            time_median(reps, || GramBackend::Blocked.gram_multi(&d.x, &d.x, &gammas, KernelKind::Gauss));
        let (t_xla_str, xla_speed) = match &xla {
            Some(rt) => {
                let be = GramBackend::Xla(rt.clone());
                // warm the executable cache, then measure
                let _ = be.gram_multi(&d.x, &d.x, &gammas, KernelKind::Gauss);
                let t_xla = time_median(reps, || be.gram_multi(&d.x, &d.x, &gammas, KernelKind::Gauss));
                (
                    format!("{:.3}s", t_xla.as_secs_f64()),
                    format!("x{:.1}", t_scalar.as_secs_f64() / t_xla.as_secs_f64().max(1e-9)),
                )
            }
            None => ("-".into(), "-".into()),
        };
        t.row(&[
            name,
            &d.dim().to_string(),
            &format!("{:.3}s", t_scalar.as_secs_f64()),
            &format!("{:.3}s", t_blocked.as_secs_f64()),
            &t_xla_str,
            &format!("x{:.1}", t_scalar.as_secs_f64() / t_blocked.as_secs_f64().max(1e-9)),
            &xla_speed,
        ]);
        // 10 γ surfaces of n×n entries per gram_multi call
        let entries = (n * n * gammas.len()) as f64;
        snap.case(
            &format!("{name}_scalar"),
            t_scalar,
            entries / t_scalar.as_secs_f64().max(1e-9),
            "entries/s",
        );
        snap.case(
            &format!("{name}_blocked"),
            t_blocked,
            entries / t_blocked.as_secs_f64().max(1e-9),
            "entries/s",
        );
    }

    // end-to-end: full training run per backend on one dataset
    println!("\n--- end-to-end training, covtype n={} ---\n", n.min(1000));
    let train = synth::by_name("covtype", n.min(1000), 10).unwrap();
    let t2 = Table::new(&["backend", "train time", "error"], &[10, 11, 8]);
    for (label, be) in [("scalar", BackendChoice::Scalar), ("blocked", BackendChoice::Blocked), ("xla", BackendChoice::Xla)] {
        if be == BackendChoice::Xla && xla.is_none() {
            continue;
        }
        let cfg = Config::default().folds(3).backend(be);
        let (m, dt) = time_once(|| svm_binary(&train, 0.5, &cfg).unwrap());
        let test = synth::by_name("covtype", 500, 11).unwrap();
        t2.row(&[
            label,
            &format!("{:.2}s", dt.as_secs_f64()),
            &format!("{:.3}", m.test(&test).error),
        ]);
        snap.case(
            &format!("train_covtype_{label}"),
            dt,
            train.len() as f64 / dt.as_secs_f64().max(1e-9),
            "rows/s",
        );
    }
    snap.write();
    println!("\npaper shape: each vectorization rung up is faster, gap grows with dim.");
}
