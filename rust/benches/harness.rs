//! Shared micro-harness for the paper-table benches.
//!
//! criterion is not in this image's offline registry, so benches are
//! `harness = false` binaries using this minimal timer: median of R
//! repetitions after a warm-up, plus a fixed-width table printer that
//! mirrors the paper's layout (relative times + absolute seconds +
//! errors).
//!
//! Scale knob: `BENCH_SCALE=smoke|default|full` (smoke for CI-speed
//! runs, full for paper-scale sizes), or pass `--quick` to the bench
//! binary (`cargo bench --bench table3_cells -- --quick`) to force
//! smoke scale — that is what CI runs so the cells path cannot rot.

#![allow(dead_code)]

use std::time::{Duration, Instant};

/// Benchmark scale from the environment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Default,
    Full,
}

pub fn scale() -> Scale {
    if std::env::args().any(|a| a == "--quick") {
        return Scale::Smoke;
    }
    match std::env::var("BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::Smoke,
        Ok("full") => Scale::Full,
        _ => Scale::Default,
    }
}

/// Pick a size by scale.
pub fn sized(smoke: usize, default: usize, full: usize) -> usize {
    match scale() {
        Scale::Smoke => smoke,
        Scale::Default => default,
        Scale::Full => full,
    }
}

/// Time one invocation (the benches here are long-running end-to-end
/// pipelines; medians over many reps would take hours, matching the
/// paper's own single-run-per-cell methodology for the big tables).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Median of `reps` timed runs (for cheap kernels).
pub fn time_median<T>(reps: usize, mut f: impl FnMut() -> T) -> Duration {
    let mut times: Vec<Duration> = Vec::with_capacity(reps);
    let mut sink = None;
    for _ in 0..reps {
        let (out, dt) = time_once(&mut f);
        sink = Some(out);
        times.push(dt);
    }
    std::hint::black_box(sink);
    times.sort();
    times[times.len() / 2]
}

/// Fixed-width row printer.
pub struct Table {
    pub widths: Vec<usize>,
}

impl Table {
    pub fn new(header: &[&str], widths: &[usize]) -> Table {
        let t = Table { widths: widths.to_vec() };
        t.row(header);
        let line: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let refs: Vec<&str> = line.iter().map(String::as_str).collect();
        t.row(&refs);
        t
    }

    pub fn row(&self, cells: &[&str]) {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            line.push_str(&format!("{c:>w$}  "));
        }
        println!("{}", line.trim_end());
    }
}

pub fn secs(d: Duration) -> String {
    format!("{:.2}s", d.as_secs_f64())
}

pub fn rel(d: Duration, base: Duration) -> String {
    format!("x{:.1}", d.as_secs_f64() / base.as_secs_f64().max(1e-9))
}

pub fn pct(e: f32) -> String {
    format!("{:.2}%", e * 100.0)
}
