//! Shared micro-harness for the paper-table benches.
//!
//! criterion is not in this image's offline registry, so benches are
//! `harness = false` binaries using this minimal timer: median of R
//! repetitions after a warm-up, plus a fixed-width table printer that
//! mirrors the paper's layout (relative times + absolute seconds +
//! errors).
//!
//! Scale knob: `BENCH_SCALE=smoke|default|full` (smoke for CI-speed
//! runs, full for paper-scale sizes), or pass `--quick` to the bench
//! binary (`cargo bench --bench table3_cells -- --quick`) to force
//! smoke scale — that is what CI runs so the cells path cannot rot.

#![allow(dead_code)]

use std::time::{Duration, Instant};

use liquid_svm::metrics::counters::{self, CounterSnapshot};

/// Benchmark scale from the environment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Default,
    Full,
}

pub fn scale() -> Scale {
    if std::env::args().any(|a| a == "--quick") {
        return Scale::Smoke;
    }
    match std::env::var("BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::Smoke,
        Ok("full") => Scale::Full,
        _ => Scale::Default,
    }
}

/// Pick a size by scale.
pub fn sized(smoke: usize, default: usize, full: usize) -> usize {
    match scale() {
        Scale::Smoke => smoke,
        Scale::Default => default,
        Scale::Full => full,
    }
}

/// Time one invocation (the benches here are long-running end-to-end
/// pipelines; medians over many reps would take hours, matching the
/// paper's own single-run-per-cell methodology for the big tables).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Median of `reps` timed runs (for cheap kernels).
pub fn time_median<T>(reps: usize, mut f: impl FnMut() -> T) -> Duration {
    let mut times: Vec<Duration> = Vec::with_capacity(reps);
    let mut sink = None;
    for _ in 0..reps {
        let (out, dt) = time_once(&mut f);
        sink = Some(out);
        times.push(dt);
    }
    std::hint::black_box(sink);
    times.sort();
    times[times.len() / 2]
}

/// Fixed-width row printer.
pub struct Table {
    pub widths: Vec<usize>,
}

impl Table {
    pub fn new(header: &[&str], widths: &[usize]) -> Table {
        let t = Table { widths: widths.to_vec() };
        t.row(header);
        let line: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let refs: Vec<&str> = line.iter().map(String::as_str).collect();
        t.row(&refs);
        t
    }

    pub fn row(&self, cells: &[&str]) {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            line.push_str(&format!("{c:>w$}  "));
        }
        println!("{}", line.trim_end());
    }
}

pub fn secs(d: Duration) -> String {
    format!("{:.2}s", d.as_secs_f64())
}

pub fn rel(d: Duration, base: Duration) -> String {
    format!("x{:.1}", d.as_secs_f64() / base.as_secs_f64().max(1e-9))
}

pub fn pct(e: f32) -> String {
    format!("{:.2}%", e * 100.0)
}

// --------------------------------------------------- perf snapshots

/// One timed case inside a bench snapshot.
struct SnapCase {
    name: String,
    wall_us: u64,
    /// work rate in `unit` (0.0 = the case has no natural rate)
    throughput: f64,
    unit: String,
}

/// Machine-readable perf snapshot of one bench run, written as
/// `BENCH_<name>.json` (schema: DESIGN.md §Observability).  Records
/// per-case wall time and throughput, the global counter deltas across
/// the whole run, and an environment fingerprint so two snapshots can
/// be compared honestly (`scripts/bench_diff.py`).
pub struct Snapshot {
    bench: String,
    cases: Vec<SnapCase>,
    before: CounterSnapshot,
}

impl Snapshot {
    /// Start a snapshot; captures the counter baseline now, so create
    /// it before the timed work runs.
    pub fn new(bench: &str) -> Snapshot {
        Snapshot { bench: bench.to_string(), cases: Vec::new(), before: counters::snapshot() }
    }

    /// Record one finished case.  `throughput` is the case's natural
    /// work rate (rows/s, entries/s, requests/s — named by `unit`);
    /// pass 0.0 when there is none.
    pub fn case(&mut self, name: &str, wall: Duration, throughput: f64, unit: &str) {
        self.cases.push(SnapCase {
            name: name.to_string(),
            wall_us: wall.as_micros() as u64,
            throughput: if throughput.is_finite() { throughput } else { 0.0 },
            unit: unit.to_string(),
        });
    }

    /// Write `BENCH_<name>.json` into `$BENCH_OUT_DIR` (or the current
    /// directory).  Failures are reported, never fatal — a read-only
    /// filesystem must not fail the bench itself.
    pub fn write(&self) {
        let delta = counters::snapshot().diff(&self.before);
        let json = self.render(&delta);
        let dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
        let path = std::path::PathBuf::from(dir).join(format!("BENCH_{}.json", self.bench));
        match std::fs::write(&path, json) {
            Ok(()) => eprintln!("snapshot: wrote {}", path.display()),
            Err(e) => eprintln!("snapshot: could not write {}: {e}", path.display()),
        }
    }

    fn render(&self, delta: &CounterSnapshot) -> String {
        let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let profile = if cfg!(debug_assertions) { "debug" } else { "release" };
        let scale = match scale() {
            Scale::Smoke => "smoke",
            Scale::Default => "default",
            Scale::Full => "full",
        };
        let unix_time = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str("  \"schema\": \"liquidsvm-bench-snapshot/v1\",\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", esc(&self.bench)));
        out.push_str("  \"seed\": false,\n");
        out.push_str(&format!(
            "  \"env\": {{\"cpus\": {cpus}, \"profile\": \"{profile}\", \"git_rev\": \"{}\", \
             \"scale\": \"{scale}\", \"unix_time\": {unix_time}}},\n",
            esc(&git_rev())
        ));
        out.push_str("  \"counters\": {");
        let pairs = [
            ("gram_cache_hits", delta.gram_cache_hits),
            ("gram_cache_misses", delta.gram_cache_misses),
            ("gram_allocs", delta.gram_allocs),
            ("xla_calls", delta.xla_calls),
            ("solver_sweeps", delta.solver_sweeps),
            ("solver_shrink_active", delta.solver_shrink_active),
            ("solver_unshrink_passes", delta.solver_unshrink_passes),
            ("cell_units_trained", delta.cell_units_trained),
            ("cell_train_us", delta.cell_train_us),
        ];
        for (i, (k, v)) in pairs.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{k}\": {v}"));
        }
        out.push_str("},\n");
        out.push_str("  \"cases\": [\n");
        for (i, c) in self.cases.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"wall_us\": {}, \"throughput\": {}, \"unit\": \"{}\"}}{}\n",
                esc(&c.name),
                c.wall_us,
                if c.throughput.is_finite() { c.throughput } else { 0.0 },
                esc(&c.unit),
                if i + 1 < self.cases.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}
