//! Table 1 / 6 / 7 reproduction: cross-validation time + errors on the
//! four small datasets, comparing
//!
//! * liquidSVM, default 10×10 grid (the paper's headline column)
//! * liquidSVM on the libsvm 10×11 grid
//! * liquidSVM "(outer cv)" — our solver driven by naive grid loops
//! * libsvm-style SMO in the same naive loops (the e1071 column)
//! * SVMlight-style disk wrapper (the klaR column)
//!
//! Paper shape to reproduce (Table 1, n=4000): default grid ≈ 0.4–0.6×
//! the libsvm-grid time; outer cv ≈ 10–15×; libsvm ≈ 13–35×;
//! SVMlight ≫ 200× (disk).  Absolute numbers differ (different
//! hardware + synthetic data); the ordering and rough factors are the
//! claim under test.

#[path = "harness.rs"]
mod harness;

use harness::{pct, rel, secs, sized, time_once, Snapshot, Table};
use liquid_svm::baselines::{disk_wrapper::disk_wrapper_cv, naive_cv};
use liquid_svm::cv::Grid;
use liquid_svm::data::synth;
use liquid_svm::prelude::*;

const DATASETS: [&str; 4] = ["bank-marketing", "cod-rna", "covtype", "thyroid-ann"];

fn main() {
    let n = sized(300, 600, 4000);
    let folds = if n <= 300 { 3 } else { 5 };
    println!("\n=== Table 1/6/7: small-set CV time (n={n}, {folds}-fold) ===\n");
    let t = Table::new(
        &["dataset", "liquid", "(libsvm g.)", "(sec.)", "(outer cv)", "libsvm", "svmlight",
          "err-liq", "err-lib"],
        &[14, 8, 11, 8, 10, 8, 9, 8, 8],
    );
    let mut snap = Snapshot::new("table1_small");

    for name in DATASETS {
        let train = synth::by_name(name, n, 42).unwrap();
        let test = synth::by_name(name, n / 2, 43).unwrap();

        // --- liquidSVM, default grid -------------------------------
        let cfg = Config::default().folds(folds);
        let (m_def, t_def) = time_once(|| svm_binary(&train, 0.5, &cfg).unwrap());
        let err_def = m_def.test(&test).error;

        // --- liquidSVM, libsvm grid --------------------------------
        let cfg_lib = Config::default().folds(folds).libsvm_grid(true);
        let (m_lib, t_lib) = time_once(|| svm_binary(&train, 0.5, &cfg_lib).unwrap());
        let err_lib = m_lib.test(&test).error;

        // --- outer-cv with our solver ------------------------------
        let grid = Grid::libsvm(n - n / folds);
        let (_, t_outer) = time_once(|| {
            naive_cv::outer_cv_liquid(&train, &grid.gammas, &grid.lambdas, folds, 42)
        });

        // --- libsvm-style SMO outer loops --------------------------
        let gl: Vec<f32> =
            [3i32, 1, -1, -3, -5, -7, -9, -11, -13, -15].iter().map(|&e| 2f32.powi(e)).collect();
        let costs: Vec<f32> =
            [-5i32, -3, -1, 1, 3, 5, 7, 9, 11, 13, 15].iter().map(|&e| 2f32.powi(e)).collect();
        let (_, t_smo) = time_once(|| naive_cv::outer_cv_smo(&train, &gl, &costs, folds, 42));

        // --- SVMlight disk wrapper ---------------------------------
        let dir = std::env::temp_dir().join(format!("lsvm-t1-{}", std::process::id()));
        let (_, t_disk) =
            time_once(|| disk_wrapper_cv(&train, &gl, &costs, folds, 42, &dir).unwrap());
        std::fs::remove_dir_all(&dir).ok();

        t.row(&[
            name,
            &rel(t_def, t_lib),
            "x1.0",
            &secs(t_lib),
            &rel(t_outer, t_lib),
            &rel(t_smo, t_lib),
            &rel(t_disk, t_lib),
            &pct(err_def),
            &pct(err_lib),
        ]);
        snap.case(
            &format!("{name}_default_grid"),
            t_def,
            n as f64 / t_def.as_secs_f64().max(1e-9),
            "rows/s",
        );
        snap.case(
            &format!("{name}_libsvm_grid"),
            t_lib,
            n as f64 / t_lib.as_secs_f64().max(1e-9),
            "rows/s",
        );
    }
    snap.write();

    println!("\npaper shape: default-grid <= libsvm-grid time; outer-cv and libsvm");
    println!("an order of magnitude slower; svmlight slowest (disk tax).");
}
