//! CV-grid bench: sequential vs parallel fold×γ grid on the Gram
//! plane, plus allocation accounting — the observable form of the
//! plane contract (per-γ Gram allocations gone from the hot loop,
//! parallel output bit-identical to sequential).
//!
//! Columns per dataset:
//! * `seq` / `par`   — wall-clock of `run_cv` at jobs=1 vs jobs=J
//! * `speedup`       — seq/par
//! * `points`        — grid points solved (γ×λ×folds)
//! * `allocs`        — `gram_allocs` counter delta over the parallel
//!                     run: stays O(workers), NOT O(points), because
//!                     each worker exponentiates every γ into one
//!                     reusable buffer
//! * `identical`     — bitwise equality of (γ*, λ*, fold coefs)
//!
//! Runs in CI as `cargo bench --bench table1_grid -- --quick`.

#[path = "harness.rs"]
mod harness;

use harness::{rel, secs, sized, time_once, Snapshot, Table};
use liquid_svm::cv::{run_cv, CvConfig, CvResult, Grid};
use liquid_svm::data::synth;
use liquid_svm::metrics::{counters, Loss};
use liquid_svm::solver::SolverKind;

fn identical(a: &CvResult, b: &CvResult) -> bool {
    a.best_gamma.to_bits() == b.best_gamma.to_bits()
        && a.best_lambda.to_bits() == b.best_lambda.to_bits()
        && a.models.len() == b.models.len()
        && a.models.iter().zip(&b.models).all(|(ma, mb)| {
            ma.coef.iter().map(|v| v.to_bits()).eq(mb.coef.iter().map(|v| v.to_bits()))
        })
}

fn main() {
    let n = sized(240, 800, 4000);
    let folds = if n <= 300 { 3 } else { 5 };
    let jobs = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(8);
    println!("\n=== CV grid: sequential vs parallel fold x gamma (n={n}, {folds}-fold, J={jobs}) ===\n");
    let t = Table::new(
        &["dataset", "seq", "par", "speedup", "points", "allocs", "identical"],
        &[14, 8, 8, 9, 8, 8, 10],
    );
    let mut snap = Snapshot::new("table1_grid");

    for name in ["bank-marketing", "cod-rna", "thyroid-ann"] {
        let train = synth::by_name(name, n, 42).unwrap();
        let n_fold = n - n / folds;
        let mut cfg = CvConfig::new(
            Grid::default_grid(0, n_fold, train.dim()),
            SolverKind::Hinge { w: 0.5 },
            Loss::Classification,
        );
        cfg.folds = folds;

        let mut seq_cfg = cfg.clone();
        seq_cfg.jobs = 1;
        let (seq_res, t_seq) = time_once(|| run_cv(&train, &seq_cfg));

        let mut par_cfg = cfg.clone();
        par_cfg.jobs = jobs;
        let before = counters::snapshot();
        let (par_res, t_par) = time_once(|| run_cv(&train, &par_cfg));
        let after = counters::snapshot();
        let allocs = after.gram_allocs - before.gram_allocs;

        t.row(&[
            name,
            &secs(t_seq),
            &secs(t_par),
            &rel(t_seq, t_par),
            &par_res.points_evaluated.to_string(),
            &allocs.to_string(),
            if identical(&seq_res, &par_res) { "yes" } else { "NO" },
        ]);
        assert!(
            identical(&seq_res, &par_res),
            "{name}: parallel CV output differs from sequential"
        );
        assert!(
            (allocs as usize) < par_res.points_evaluated,
            "{name}: gram_allocs {allocs} not sub-linear in grid points \
             ({}) — per-γ allocation crept back into the hot loop",
            par_res.points_evaluated
        );
        snap.case(
            &format!("{name}_seq"),
            t_seq,
            seq_res.points_evaluated as f64 / t_seq.as_secs_f64().max(1e-9),
            "points/s",
        );
        snap.case(
            &format!("{name}_par"),
            t_par,
            par_res.points_evaluated as f64 / t_par.as_secs_f64().max(1e-9),
            "points/s",
        );
    }
    snap.write();

    println!("\nplane contract: allocs ~ O(workers+folds) while points ~ O(folds x grid);");
    println!("parallel selection and fold coefficients bitwise equal to sequential.");
}
