//! Tables 10–13 reproduction: liquidSVM configuration ablations on the
//! small datasets — training time (relative to the baseline config) and
//! error for:
//!
//!   threads=1..4, grid_choice=1/2, adaptivity_control=1/2,
//!   voronoi=5/6 (± explicit 1000-cap), and the combined
//!   adaptivity_control=2+grid_choice=2 row.
//!
//! Paper shape (n=4000, Table 12): grid_choice=1 ≈ 2–3×, grid_choice=2
//! ≈ 7–15×, adaptivity_control < 1×, voronoi=6 ≈ 0.45–0.5× with ~equal
//! error.  (threads>1 speedups need >1 core; on this 1-core image the
//! thread rows measure scheduler overhead instead and are labelled so.)

#[path = "harness.rs"]
mod harness;

use harness::{pct, sized, time_once, Snapshot, Table};
use liquid_svm::cells::CellStrategy;
use liquid_svm::data::synth;
use liquid_svm::prelude::*;

fn main() {
    let n = sized(400, 1000, 4000);
    println!("\n=== Tables 10-13: configuration ablations (n={n}) ===\n");
    let datasets = ["bank-marketing", "cod-rna", "covtype", "thyroid-ann"];
    let t = Table::new(
        &["config", "bank-mkt", "cod-rna", "covtype", "thyroid", "err-bank", "err-cod"],
        &[26, 9, 9, 9, 9, 9, 9],
    );

    let base_cfg = Config::default().folds(5);
    let mut snap = Snapshot::new("table10_config");
    let mut base_times = Vec::new();
    let mut row_err = Vec::new();
    for name in datasets {
        let train = synth::by_name(name, n, 3).unwrap();
        let test = synth::by_name(name, n / 2, 4).unwrap();
        let (m, dt) = time_once(|| svm_binary(&train, 0.5, &base_cfg).unwrap());
        snap.case(
            &format!("baseline_{name}"),
            dt,
            n as f64 / dt.as_secs_f64().max(1e-9),
            "rows/s",
        );
        base_times.push(dt);
        row_err.push(m.test(&test).error);
    }
    t.row(&[
        "baseline (threads=1)",
        "x1.00", "x1.00", "x1.00", "x1.00",
        &pct(row_err[0]), &pct(row_err[1]),
    ]);

    let configs: Vec<(&str, Config)> = vec![
        ("threads=2 (1-core ovh)", base_cfg.clone().threads(2)),
        ("threads=4 (1-core ovh)", base_cfg.clone().threads(4)),
        ("grid_choice=1", base_cfg.clone().grid_choice(1)),
        ("grid_choice=2", base_cfg.clone().grid_choice(2)),
        ("adaptivity_control=1", base_cfg.clone().adaptivity(1)),
        ("adaptivity_control=2", base_cfg.clone().adaptivity(2)),
        ("adapt=2, grid=2", base_cfg.clone().adaptivity(2).grid_choice(2)),
        ("voronoi=5", base_cfg.clone().voronoi(CellStrategy::OverlappingVoronoi { size: 2000, overlap: 0.25 })),
        ("voronoi=6", base_cfg.clone().voronoi(CellStrategy::RecursiveTree { max_size: 2000 })),
        ("voronoi=c(5,1000)", base_cfg.clone().voronoi(CellStrategy::OverlappingVoronoi { size: 1000, overlap: 0.25 })),
        ("voronoi=c(6,1000)", base_cfg.clone().voronoi(CellStrategy::RecursiveTree { max_size: 1000 })),
    ];

    for (label, cfg) in configs {
        let mut cells = vec![label.to_string()];
        let mut errs = Vec::new();
        for (di, name) in datasets.iter().enumerate() {
            let train = synth::by_name(name, n, 3).unwrap();
            let test = synth::by_name(name, n / 2, 4).unwrap();
            let (m, dt) = time_once(|| svm_binary(&train, 0.5, &cfg).unwrap());
            cells.push(format!("x{:.2}", dt.as_secs_f64() / base_times[di].as_secs_f64()));
            errs.push(m.test(&test).error);
        }
        cells.push(pct(errs[0]));
        cells.push(pct(errs[1]));
        let refs: Vec<&str> = cells.iter().map(String::as_str).collect();
        t.row(&refs);
    }
    snap.write();

    println!("\npaper shape (Table 12, n=4000): grid_choice=1 ~x2-3, grid_choice=2");
    println!("~x7-15, adaptivity <x1, voronoi=6 <=x0.5 at n>=4000, errors stable.");
}
