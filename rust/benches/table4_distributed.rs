//! Table 4 reproduction: the distributed (Spark-sim) mode on large
//! sets — coarse Voronoi cells shuffled to workers, fine cells inside.
//!
//! Paper shape: near/super-linear speedup vs single node at equal
//! error (±0.5%); the single-node column pays sequential cell training
//! plus CLI overhead.  Here the worker parallelism is *modelled*
//! (1-core image): distributed time = critical path over workers +
//! shuffle, single-node = sequential sum + 10% overhead (see
//! rust/src/distributed/).

#[path = "harness.rs"]
mod harness;

use harness::{pct, sized, time_once, Snapshot, Table};
use liquid_svm::data::synth;
use liquid_svm::distributed::{train_distributed, ClusterSpec};
use liquid_svm::prelude::*;
use liquid_svm::tasks::TaskSpec;

fn main() {
    let n = sized(3000, 8000, 100_000);
    let workers = 14;
    println!("\n=== Table 4: distributed mode ({workers} workers, n={n}) ===\n");
    let t = Table::new(
        &["dataset", "n", "cells", "dist(s)", "single(s)", "speedup", "err-dist", "err-single"],
        &[9, 8, 7, 9, 10, 8, 9, 11],
    );
    let mut snap = Snapshot::new("table4_distributed");

    for name in ["covtype", "susy"] {
        let train = synth::by_name(name, n, 31).unwrap();
        let test = synth::by_name(name, (n / 5).max(500), 32).unwrap();
        let cluster = ClusterSpec {
            workers,
            coarse_size: (n / 10).max(500),
            fine_size: sized(150, 500, 2000),
            driver_sample: 4000,
        };
        let cfg = Config::default().folds(5);
        let (model, _wall) = time_once(|| {
            train_distributed(&train, &TaskSpec::Binary { w: 0.5 }, &cfg, &cluster).unwrap()
        });
        let err_dist = model.test_error(&test);

        // single-node reference: same engine, same fine cells, one box
        let cfg_sn = Config::default().folds(5).voronoi(
            liquid_svm::cells::CellStrategy::RecursiveTree { max_size: cluster.fine_size },
        );
        let (m_sn, t_sn) = time_once(|| svm_binary(&train, 0.5, &cfg_sn).unwrap());
        let err_sn = m_sn.test(&test).error;

        t.row(&[
            name,
            &n.to_string(),
            &model.stats.n_coarse_cells.to_string(),
            &format!("{:.2}", model.stats.distributed_time.as_secs_f64()),
            &format!("{:.2}", t_sn.as_secs_f64()),
            &format!("{:.1}x", t_sn.as_secs_f64() / model.stats.distributed_time.as_secs_f64().max(1e-9)),
            &pct(err_dist),
            &pct(err_sn),
        ]);
        // measured_wall is the real concurrent grid wall (not the
        // modelled critical path) — the honest throughput denominator
        snap.case(
            &format!("{name}_distributed"),
            model.stats.measured_wall,
            n as f64 / model.stats.measured_wall.as_secs_f64().max(1e-9),
            "rows/s",
        );
        snap.case(
            &format!("{name}_single_node"),
            t_sn,
            n as f64 / t_sn.as_secs_f64().max(1e-9),
            "rows/s",
        );
    }
    snap.write();
    println!("\npaper shape: speedup near the worker count (super-linear in the");
    println!("paper due to single-node CLI overhead), errors within ~0.5%.");
}
