//! Table 4 reproduction: the distributed (Spark-sim) mode on large
//! sets — coarse Voronoi cells shuffled to workers, fine cells inside.
//!
//! Paper shape: near/super-linear speedup vs single node at equal
//! error (±0.5%); the single-node column pays sequential cell training
//! plus CLI overhead.  In the Spark-sim table the worker parallelism
//! is *modelled* (1-core image): distributed time = critical path over
//! workers + shuffle, single-node = sequential sum + 10% overhead (see
//! rust/src/distributed/).
//!
//! Table 4b then runs the *real* train wire on loopback sockets
//! (DESIGN.md §Distributed-wire): in-process workers behind actual
//! TCP streams, so `measured(s)` is socket-measured wall-clock —
//! serialization, framing and dispatch included — printed next to the
//! simulation's modelled critical path for the same assignment.

#[path = "harness.rs"]
mod harness;

use harness::{pct, sized, time_once, Snapshot, Table};
use liquid_svm::data::synth;
use liquid_svm::distributed::{
    train_distributed, train_distributed_wire, ClusterSpec, WireOptions, WireWorker,
    WorkerOptions,
};
use liquid_svm::prelude::*;
use liquid_svm::tasks::TaskSpec;

fn main() {
    let n = sized(3000, 8000, 100_000);
    let workers = 14;
    println!("\n=== Table 4: distributed mode ({workers} workers, n={n}) ===\n");
    let t = Table::new(
        &["dataset", "n", "cells", "dist(s)", "single(s)", "speedup", "err-dist", "err-single"],
        &[9, 8, 7, 9, 10, 8, 9, 11],
    );
    let mut snap = Snapshot::new("table4_distributed");

    for name in ["covtype", "susy"] {
        let train = synth::by_name(name, n, 31).unwrap();
        let test = synth::by_name(name, (n / 5).max(500), 32).unwrap();
        let cluster = ClusterSpec {
            workers,
            coarse_size: (n / 10).max(500),
            fine_size: sized(150, 500, 2000),
            driver_sample: 4000,
        };
        let cfg = Config::default().folds(5);
        let (model, _wall) = time_once(|| {
            train_distributed(&train, &TaskSpec::Binary { w: 0.5 }, &cfg, &cluster).unwrap()
        });
        let err_dist = model.test_error(&test);

        // single-node reference: same engine, same fine cells, one box
        let cfg_sn = Config::default().folds(5).voronoi(
            liquid_svm::cells::CellStrategy::RecursiveTree { max_size: cluster.fine_size },
        );
        let (m_sn, t_sn) = time_once(|| svm_binary(&train, 0.5, &cfg_sn).unwrap());
        let err_sn = m_sn.test(&test).error;

        t.row(&[
            name,
            &n.to_string(),
            &model.stats.n_coarse_cells.to_string(),
            &format!("{:.2}", model.stats.distributed_time.as_secs_f64()),
            &format!("{:.2}", t_sn.as_secs_f64()),
            &format!("{:.1}x", t_sn.as_secs_f64() / model.stats.distributed_time.as_secs_f64().max(1e-9)),
            &pct(err_dist),
            &pct(err_sn),
        ]);
        // measured_wall is the real concurrent grid wall (not the
        // modelled critical path) — the honest throughput denominator
        snap.case(
            &format!("{name}_distributed"),
            model.stats.measured_wall,
            n as f64 / model.stats.measured_wall.as_secs_f64().max(1e-9),
            "rows/s",
        );
        snap.case(
            &format!("{name}_single_node"),
            t_sn,
            n as f64 / t_sn.as_secs_f64().max(1e-9),
            "rows/s",
        );
    }
    // ---- Table 4b: the real wire, measured on loopback sockets
    let n_wire = sized(1000, 3000, 20_000);
    println!("\n=== Table 4b: train wire on loopback (measured, not modelled; n={n_wire}) ===\n");
    let t2 = Table::new(
        &["workers", "cell-sz", "cells", "measured(s)", "modelled(s)", "single(s)", "tx(KB)", "rx(KB)"],
        &[7, 7, 6, 11, 11, 9, 7, 7],
    );
    let wire_train = synth::by_name("covtype", n_wire, 77).unwrap();
    let out = std::env::temp_dir().join(format!("lsvm-bench-wire-{}.sol.d", std::process::id()));
    for cell_size in [sized(120, 300, 1000), sized(250, 600, 2000)] {
        let cfg = Config::default()
            .folds(sized(2, 3, 5))
            .voronoi(liquid_svm::cells::CellStrategy::Voronoi { size: cell_size });
        for n_workers in [1usize, 2, 4] {
            let fleet: Vec<WireWorker> = (0..n_workers)
                .map(|_| WireWorker::spawn_local(WorkerOptions::default()).unwrap())
                .collect();
            let addrs: Vec<String> = fleet.iter().map(|w| w.addr()).collect();
            let report = train_distributed_wire(
                &wire_train,
                &TaskSpec::Binary { w: 0.5 },
                &cfg,
                &addrs,
                &out,
                &WireOptions::default(),
            )
            .unwrap();
            assert_eq!(report.redispatched, 0, "loopback run lost a worker");
            t2.row(&[
                &n_workers.to_string(),
                &cell_size.to_string(),
                &report.n_cells.to_string(),
                &format!("{:.2}", report.measured_wall.as_secs_f64()),
                &format!("{:.2}", report.modelled_distributed.as_secs_f64()),
                &format!("{:.2}", report.modelled_single_node.as_secs_f64()),
                &(report.bytes_tx / 1024).to_string(),
                &(report.bytes_rx / 1024).to_string(),
            ]);
            snap.case(
                &format!("wire_w{n_workers}_c{cell_size}"),
                report.measured_wall,
                n_wire as f64 / report.measured_wall.as_secs_f64().max(1e-9),
                "rows/s",
            );
        }
    }
    std::fs::remove_dir_all(&out).ok();

    snap.write();
    println!("\npaper shape: speedup near the worker count (super-linear in the");
    println!("paper due to single-node CLI overhead), errors within ~0.5%.");
    println!("wire shape: measured wall tracks the modelled critical path plus");
    println!("serialization; tx/rx bytes scale with rows and shard sizes.");
}
