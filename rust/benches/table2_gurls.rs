//! Table 2 reproduction: multiclass OvA least-squares — liquidSVM's
//! integrated LS-CV vs the GURLS-style baseline (fresh Cholesky per λ,
//! quartile-heuristic bandwidth, hold-out λ selection).
//!
//! Paper shape: liquidSVM 7–35× faster with equal-or-better error.

#[path = "harness.rs"]
mod harness;

use harness::{pct, secs, sized, time_once, Snapshot, Table};
use liquid_svm::baselines::gurls::train_gurls;
use liquid_svm::coordinator::scenarios::mc_svm_type;
use liquid_svm::data::synth;
use liquid_svm::prelude::*;
use liquid_svm::tasks::TaskSpec;

fn main() {
    let n = sized(300, 600, 3000);
    println!("\n=== Table 2: OvA least-squares vs GURLS (n={n}) ===\n");
    let t = Table::new(
        &["dataset", "classes", "ours(s)", "gurls(s)", "factor", "err-ours", "err-gurls"],
        &[10, 8, 9, 9, 8, 9, 10],
    );
    let mut snap = Snapshot::new("table2_gurls");

    for name in ["optdigit", "landsat", "pendigit", "covtype"] {
        let train = synth::by_name(name, n, 7).unwrap();
        let test = synth::by_name(name, n / 2, 8).unwrap();
        let classes = train.classes().len();

        // ours: OvA with the least-squares solver, integrated CV
        let cfg = Config::default().folds(5);
        let (model, t_ours) = time_once(|| {
            liquid_svm::coordinator::train(&train, &TaskSpec::MultiClassOvALs, &cfg).unwrap()
        });
        let err_ours = model.test(&test).error;

        // GURLS: per-λ factorizations
        let lambdas = [1e-2f32, 1e-3, 1e-4, 1e-5, 1e-6];
        let (g, t_gurls) = time_once(|| train_gurls(&train, &lambdas, 7));
        let err_gurls = g.test_error(&test);

        t.row(&[
            name,
            &classes.to_string(),
            &secs(t_ours),
            &secs(t_gurls),
            &format!("x{:.1}", t_gurls.as_secs_f64() / t_ours.as_secs_f64().max(1e-9)),
            &pct(err_ours),
            &pct(err_gurls),
        ]);
        // binary covtype appears in the paper's Table 2 as the last row
        let _ = mc_svm_type; // (kept for API parity; OvA-LS used above)
        snap.case(
            &format!("{name}_ova_ls"),
            t_ours,
            n as f64 / t_ours.as_secs_f64().max(1e-9),
            "rows/s",
        );
        snap.case(
            &format!("{name}_gurls"),
            t_gurls,
            n as f64 / t_gurls.as_secs_f64().max(1e-9),
            "rows/s",
        );
    }
    snap.write();
    println!("\npaper shape: ours faster by x7-x35 with comparable-or-better error.");
}
