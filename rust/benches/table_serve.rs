//! Serving-throughput table: single-request vs batched prediction
//! through the `serve` subsystem, across batch-size caps and Gram
//! backends.
//!
//! "single" runs one connection in strict request/response lockstep
//! against a `max_batch = 1` server — every row pays the full
//! per-call cost (syscalls, routing, a 1-row Gram).  "batched" runs
//! many pipelined connections against a size-bucketed batcher, so
//! rows coalesce into fused predict calls and the per-call overhead
//! amortizes — the request-level analogue of the CV engine reusing
//! one distance matrix across the whole γ grid.
//!
//! Paper shape: batched throughput grows with the batch cap until the
//! predict call saturates the backend; the speedup column is the
//! serving claim of this PR (target ≥ 3× on Blocked).

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use harness::{scale, sized, Scale, Snapshot, Table};
use liquid_svm::coordinator::config::BackendChoice;
use liquid_svm::data::synth;
use liquid_svm::prelude::*;
use liquid_svm::runtime::{default_artifact_dir, XlaRuntime};
use liquid_svm::serve::protocol::WireMode;
use liquid_svm::serve::{run_load, run_swarm, LoadSpec, ServeConfig, Server};

struct Measured {
    rps: f64,
    mean_batch: f64,
    p99_us: u64,
}

fn measure(
    backend: BackendChoice,
    train: &liquid_svm::data::Dataset,
    rows: &[Vec<f32>],
    max_batch: usize,
    connections: usize,
    pipeline: usize,
    requests: usize,
) -> Measured {
    let cfg = Config::default().folds(2).backend(backend);
    let model = svm_binary(train, 0.5, &cfg).unwrap();
    let server = Server::start(ServeConfig {
        port: 0,
        max_batch,
        max_delay: Duration::from_millis(1),
        workers: 2,
        model_config: cfg,
        ..ServeConfig::default()
    })
    .unwrap();
    server.registry.insert("m", model);

    let spec = LoadSpec {
        addr: server.addr().to_string(),
        model: "m".into(),
        connections,
        requests: requests / connections.max(1),
        pipeline,
    };
    // warm-up (thread spin-up, executable caches), then the timed run
    let _ = run_load(&LoadSpec { requests: (spec.requests / 10).max(1), ..spec.clone() }, rows, None);
    let report = run_load(&spec, rows, None).unwrap();
    let out = Measured {
        rps: report.rps(),
        mean_batch: server.stats.mean_batch(),
        p99_us: report.latency.percentile_us(0.99),
    };
    server.shutdown();
    out
}

/// Soft open-file limit from `/proc/self/limits` — the c10k sweep
/// needs one fd per connection on each side plus server/runtime slack.
/// Unparseable (non-Linux) reads as unlimited.
fn open_file_limit() -> usize {
    let Ok(text) = std::fs::read_to_string("/proc/self/limits") else {
        return usize::MAX;
    };
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("Max open files") {
            let soft = rest.split_whitespace().next().unwrap_or("unlimited");
            return soft.parse().unwrap_or(usize::MAX);
        }
    }
    usize::MAX
}

/// One timed swarm run against a fresh batched server (batch cap 64 —
/// the regime where the binary framing's parse savings dominate).
fn measure_swarm(
    train: &liquid_svm::data::Dataset,
    rows: &[Vec<f32>],
    mode: WireMode,
    connections: usize,
    per_conn: usize,
    pipeline: usize,
) -> Measured {
    let cfg = Config::default().folds(2).backend(BackendChoice::Blocked);
    let model = svm_binary(train, 0.5, &cfg).unwrap();
    let server = Server::start(ServeConfig {
        port: 0,
        max_batch: 64,
        max_delay: Duration::from_millis(1),
        workers: 4,
        model_config: cfg,
        ..ServeConfig::default()
    })
    .unwrap();
    server.registry.insert("m", model);

    let spec = LoadSpec {
        addr: server.addr().to_string(),
        model: "m".into(),
        connections,
        requests: per_conn,
        pipeline,
    };
    // warm-up at 1/10 the connection count, then the timed run; the
    // swarm itself bails on any dropped reply (strict accounting)
    let warm = LoadSpec { connections: (connections / 10).max(1), ..spec.clone() };
    let _ = run_swarm(&warm, rows, None, mode).unwrap();
    let report = run_swarm(&spec, rows, None, mode).unwrap();
    assert_eq!(report.failed, 0, "c10k sweep saw failed replies: {report:?}");
    let out = Measured {
        rps: report.rps(),
        mean_batch: server.stats.mean_batch(),
        p99_us: report.latency.percentile_us(0.99),
    };
    server.shutdown();
    out
}

fn main() {
    let n_train = sized(150, 400, 1000);
    let requests = sized(2_000, 8_000, 20_000);
    println!(
        "\n=== serve: single-request vs batched throughput (train n={n_train}, {requests} requests) ===\n"
    );

    let train = synth::banana_binary(n_train, 51);
    let test = synth::banana_binary(512, 52);
    let rows: Vec<Vec<f32>> = (0..test.len()).map(|i| test.x.row(i).to_vec()).collect();

    let have_artifacts = XlaRuntime::open(default_artifact_dir()).is_ok();
    let mut backends = vec![
        ("scalar", BackendChoice::Scalar),
        ("blocked", BackendChoice::Blocked),
    ];
    if have_artifacts {
        backends.push(("xla", BackendChoice::Xla));
    } else {
        println!("(artifacts missing — run `make artifacts` to include the xla rung)\n");
    }

    let t = Table::new(
        &["backend", "mode", "batch", "rps", "mean_batch", "p99", "speedup"],
        &[8, 9, 6, 10, 10, 9, 8],
    );
    let mut snap = Snapshot::new("table_serve");

    for (label, backend) in backends {
        // baseline: lockstep single requests, no server-side batching
        let single = measure(backend, &train, &rows, 1, 1, 1, requests / 4);
        t.row(&[
            label,
            "single",
            "1",
            &format!("{:.0}", single.rps),
            &format!("{:.1}", single.mean_batch),
            &format!("{}us", single.p99_us),
            "x1.0",
        ]);
        snap.case(
            &format!("{label}_single"),
            Duration::from_secs_f64((requests / 4) as f64 / single.rps.max(1e-9)),
            single.rps,
            "requests/s",
        );
        for max_batch in [8usize, 32, 64] {
            let b = measure(backend, &train, &rows, max_batch, 16, 32, requests);
            t.row(&[
                label,
                "batched",
                &max_batch.to_string(),
                &format!("{:.0}", b.rps),
                &format!("{:.1}", b.mean_batch),
                &format!("{}us", b.p99_us),
                &format!("x{:.1}", b.rps / single.rps.max(1e-9)),
            ]);
            snap.case(
                &format!("{label}_batched_{max_batch}"),
                Duration::from_secs_f64(requests as f64 / b.rps.max(1e-9)),
                b.rps,
                "requests/s",
            );
        }
    }
    // ── async c10k sweep: the reactor plane, binary vs text framing ──
    // Thousands of connections from the event-driven swarm generator
    // against the epoll serve loop; at batch cap 64 the text rows pay
    // a float parse/format per value, the binary rows memcpy.
    let want_conns = sized(200, 2_000, 10_000);
    let per_conn = 5usize;
    let limit = open_file_limit();
    // client fd + server fd per connection, plus listener/pipes/stdio
    let (conns, constrained) = if want_conns.saturating_mul(2) + 256 > limit {
        let clamped = (limit.saturating_sub(256) / 2).max(16);
        println!(
            "\nSKIP (constrained CI): open-file limit {limit} cannot hold \
             {want_conns} connections — clamping the c10k sweep to {clamped} \
             and skipping the binary>=text assertion; raise `ulimit -n` \
             (scripts/serve_stress.sh does) for the real sweep.\n"
        );
        (clamped, true)
    } else {
        (want_conns, false)
    };

    println!("\n=== serve: async c10k sweep ({conns} conns x {per_conn} reqs, batch cap 64) ===\n");
    let t2 = Table::new(
        &["mode", "conns", "rps", "mean_batch", "p99", "speedup"],
        &[8, 7, 10, 10, 9, 8],
    );
    // two runs per mode, best-of (the sweep is syscall-bound and
    // noisy; best-of-2 damps scheduler jitter without hiding a real
    // ordering inversion)
    let best = |mode| {
        let a = measure_swarm(&train, &rows, mode, conns, per_conn, 4);
        let b = measure_swarm(&train, &rows, mode, conns, per_conn, 4);
        if a.rps >= b.rps { a } else { b }
    };
    let txt = best(WireMode::Text);
    let bin = best(WireMode::Binary);
    for (label, m, base) in [("text", &txt, txt.rps), ("binary", &bin, txt.rps)] {
        t2.row(&[
            label,
            &conns.to_string(),
            &format!("{:.0}", m.rps),
            &format!("{:.1}", m.mean_batch),
            &format!("{}us", m.p99_us),
            &format!("x{:.2}", m.rps / base.max(1e-9)),
        ]);
    }
    let total = (conns * per_conn) as f64;
    snap.case(
        "async_c10k_text",
        Duration::from_secs_f64(total / txt.rps.max(1e-9)),
        txt.rps,
        "requests/s",
    );
    snap.case(
        "async_c10k_binary",
        Duration::from_secs_f64(total / bin.rps.max(1e-9)),
        bin.rps,
        "requests/s",
    );

    // the PR's serving acceptance, checked where CI runs it (--quick):
    // binary framing must not lose to text at batch cap 64
    if scale() == Scale::Smoke && !constrained {
        assert!(
            bin.rps >= txt.rps,
            "binary framing slower than text at batch 64: {:.0} vs {:.0} rps",
            bin.rps,
            txt.rps
        );
    }
    snap.write();

    println!(
        "\npaper shape: batched rps climbs with the batch cap; the blocked rung's\n\
         batched/single ratio is the headline (acceptance: >= 3x).  the c10k\n\
         sweep's headline is binary >= text rps at batch cap 64 with zero\n\
         dropped replies."
    );
}
