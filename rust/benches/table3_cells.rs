//! Table 3 / 8 / 9 reproduction: mid-size sets with cell decomposition.
//!
//! Columns: liquidSVM (default grid, recursive cells), liquidSVM on the
//! libsvm grid, Overlap (our solver, overlapping Voronoi cells),
//! Bsvm (BudgetedSVM-style LLSVM at budget k), Esvm (EnsembleSVM-style
//! bagged SMO on chunks of k).
//!
//! Paper shape (k=1000): liquidSVM ≈ libsvm-grid ≈ 1×; Overlap a few ×;
//! Bsvm ~400–550×; Esvm ~40–475×; liquidSVM errors clearly below the
//! budget baselines, Overlap slightly better still.
//!
//! CI runs `cargo bench --bench table3_cells -- --quick` (smoke sizes)
//! so the cells path is exercised on every push.

#[path = "harness.rs"]
mod harness;

use harness::{pct, rel, secs, sized, time_once, Snapshot, Table};
use liquid_svm::baselines::{ensemble::train_ensemble, llsvm::train_llsvm};
use liquid_svm::cells::CellStrategy;
use liquid_svm::data::synth;
use liquid_svm::prelude::*;

fn main() {
    let cell = sized(200, 400, 1000);
    let sets: Vec<(&str, usize)> = match harness::scale() {
        harness::Scale::Smoke => vec![("covtype", 1000), ("ijcnn1", 800)],
        harness::Scale::Default => vec![("covtype", 2000), ("covtype", 5000), ("ijcnn1", 2500), ("webspam", 1200)],
        harness::Scale::Full => vec![("covtype", 10_000), ("covtype", 40_000), ("ijcnn1", 20_000), ("webspam", 8000)],
    };
    println!("\n=== Table 3/8/9: cell decomposition, k={cell} ===\n");
    let t = Table::new(
        &["dataset", "n", "liquid", "(sec.)", "(libsvm g.)", "overlap", "bsvm", "esvm",
          "e-liq", "e-ovl", "e-bsvm", "e-esvm"],
        &[9, 7, 7, 8, 11, 8, 7, 7, 7, 7, 7, 7],
    );
    let mut snap = Snapshot::new("table3_cells");

    for (name, n) in sets {
        let train = synth::by_name(name, n, 5).unwrap();
        let test = synth::by_name(name, (n / 4).max(500), 6).unwrap();

        // liquidSVM, default grid + recursive cells
        let cfg = Config::default().folds(5).voronoi(CellStrategy::RecursiveTree { max_size: cell });
        let (m, t_liq) = time_once(|| svm_binary(&train, 0.5, &cfg).unwrap());
        let e_liq = m.test(&test).error;

        // libsvm grid variant
        let cfg_lib = cfg.clone();
        let cfg_lib = Config { use_libsvm_grid: true, ..cfg_lib };
        let (_, t_lib) = time_once(|| svm_binary(&train, 0.5, &cfg_lib).unwrap());

        // Overlap: overlapping Voronoi cells, our solver
        let cfg_ovl = Config::default()
            .folds(5)
            .voronoi(CellStrategy::OverlappingVoronoi { size: cell, overlap: 0.5 });
        let (m_ovl, t_ovl) = time_once(|| svm_binary(&train, 0.5, &cfg_ovl).unwrap());
        let e_ovl = m_ovl.test(&test).error;

        // Bsvm: LLSVM at budget k, small manual grid (their scripts)
        let (bs, t_bsvm) = time_once(|| {
            let gammas = [1.0f32, 3.0];
            let lambdas = [1e-4f32, 1e-5];
            let mut best: Option<(f32, _)> = None;
            for &g in &gammas {
                for &l in &lambdas {
                    let m = train_llsvm(&train, cell, g, l, 3, 9);
                    let e = m.test_error(&test);
                    if best.as_ref().map_or(true, |(be, _)| e < *be) {
                        best = Some((e, m));
                    }
                }
            }
            best.unwrap()
        });
        let e_bsvm = bs.0;

        // Esvm: bagged SMO on chunks of k (n/k members like EnsembleSVM)
        let members = (n / cell).clamp(3, 15);
        let (es, t_esvm) = time_once(|| {
            let gammas = [1.0f32, 3.0];
            let costs = [1.0f32, 100.0];
            let mut best: Option<f32> = None;
            for &g in &gammas {
                for &c in &costs {
                    let m = train_ensemble(&train, cell, members, g, c, 11);
                    let e = m.test_error(&test);
                    if best.map_or(true, |be| e < be) {
                        best = Some(e);
                    }
                }
            }
            best.unwrap()
        });
        let e_esvm = es;

        t.row(&[
            name,
            &n.to_string(),
            "x1.0",
            &secs(t_liq),
            &rel(t_lib, t_liq),
            &rel(t_ovl, t_liq),
            &rel(t_bsvm, t_liq),
            &rel(t_esvm, t_liq),
            &pct(e_liq),
            &pct(e_ovl),
            &pct(e_bsvm),
            &pct(e_esvm),
        ]);
        snap.case(
            &format!("{name}_{n}_recursive_cells"),
            t_liq,
            n as f64 / t_liq.as_secs_f64().max(1e-9),
            "rows/s",
        );
        snap.case(
            &format!("{name}_{n}_overlap"),
            t_ovl,
            n as f64 / t_ovl.as_secs_f64().max(1e-9),
            "rows/s",
        );
    }
    snap.write();
    println!("\npaper shape: budget baselines orders of magnitude slower at equal k,");
    println!("with worse errors; overlap slightly better error at a few x the time.");
}
