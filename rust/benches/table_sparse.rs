//! Sparse data plane bench: dense vs CSR train+predict at growing
//! dimension (density held sub-percent, the rcv1/url/webspam-class
//! shape).  What the paper's large-scale claims actually stress is
//! *data* memory, not FLOPs — the Gram state is n² either way, but the
//! dense sample matrix grows as n·d while the CSR triplet grows as
//! n·nnz.  Columns:
//!
//! * `dense_MB` / `csr_MB` — resident sample bytes of each path
//!   (`rows·cols·4` vs the CSR triplet)
//! * `t_dense` / `t_csr`   — wall-clock of train+predict ("-" when the
//!   dense path is skipped past the crossover dimension)
//! * `identical`           — bitwise equality of the two paths'
//!   predictions (the plane contract, asserted)
//!
//! Runs in CI as `cargo bench --bench table_sparse -- --quick`, which
//! asserts that the CSR footprint stays below the dense one at
//! d ≥ 10⁴ and that predictions match bitwise wherever both run.

#[path = "harness.rs"]
mod harness;

use harness::{secs, sized, time_once, Snapshot, Table};
use liquid_svm::coordinator::{train, train_sparse};
use liquid_svm::data::synth;
use liquid_svm::prelude::*;
use liquid_svm::tasks::TaskSpec;

fn main() {
    let n = sized(160, 400, 1200);
    let n_test = n / 2;
    let density = 0.005f32; // 0.5%
    let dims: &[usize] = match harness::scale() {
        harness::Scale::Smoke => &[1_000, 10_000],
        harness::Scale::Default => &[2_000, 10_000, 50_000],
        harness::Scale::Full => &[2_000, 10_000, 50_000, 100_000],
    };
    // past this, the dense twin is pointless to materialize — exactly
    // the regime the CSR plane exists for
    let dense_cap = 10_000usize;

    println!("\n=== sparse data plane: dense vs CSR (n={n}, density {:.1}%) ===\n", density * 100.0);
    let t = Table::new(
        &["d", "nnz/row", "dense_MB", "csr_MB", "t_dense", "t_csr", "identical"],
        &[8, 8, 9, 9, 9, 9, 10],
    );

    let mut snap = Snapshot::new("table_sparse");
    let mut cfg = Config::default().folds(2).max_gram_mb(256);
    cfg.scale = None; // scaling is a densification boundary; keep both paths identical
    let spec = TaskSpec::Binary { w: 0.5 };

    for &d in dims {
        let train_d = synth::sparse_binary(n, d, density, 42);
        let test_d = synth::sparse_binary(n_test, d, density, 43);
        let dense_bytes = n * d * 4;
        let csr_bytes = train_d.x.bytes();

        let (sparse_preds, t_csr) = time_once(|| {
            let m = train_sparse(&train_d, &spec, &cfg).unwrap();
            m.test_sparse(&test_d).predictions
        });
        snap.case(
            &format!("d{d}_csr"),
            t_csr,
            n as f64 / t_csr.as_secs_f64().max(1e-9),
            "rows/s",
        );

        let (dense_cell, identical) = if d <= dense_cap {
            let dd = train_d.to_dense();
            let dt = test_d.to_dense();
            let (dense_preds, t_dense) = time_once(|| {
                let m = train(&dd, &spec, &cfg).unwrap();
                m.test(&dt).predictions
            });
            let same = dense_preds.len() == sparse_preds.len()
                && dense_preds
                    .iter()
                    .zip(&sparse_preds)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "d={d}: sparse predictions diverged from the densified path");
            snap.case(
                &format!("d{d}_dense"),
                t_dense,
                n as f64 / t_dense.as_secs_f64().max(1e-9),
                "rows/s",
            );
            (secs(t_dense), "yes")
        } else {
            ("-".to_string(), "skipped")
        };

        t.row(&[
            &d.to_string(),
            &(train_d.x.nnz() / n).to_string(),
            &format!("{:.1}", dense_bytes as f64 / (1 << 20) as f64),
            &format!("{:.2}", csr_bytes as f64 / (1 << 20) as f64),
            &dense_cell,
            &secs(t_csr),
            identical,
        ]);

        if d >= 10_000 {
            assert!(
                csr_bytes < dense_bytes,
                "d={d}: CSR bytes {csr_bytes} not below dense {dense_bytes}"
            );
        }
    }
    snap.write();

    println!("\ncontract: CSR sample bytes scale with nnz (dense with n*d), and the");
    println!("sparse path's predictions are bit-identical to training on the densified data.");
}
