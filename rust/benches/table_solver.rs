//! Solver-core bench: total coordinate sweeps + wall time per loss,
//! shrink on/off × cold / λ-warm / (γ,λ)-warm (DESIGN.md
//! §Solver-core).
//!
//! Each cell walks the same little (2 γ × 4 λ) grid a CV fold would:
//!
//! * `cold`    — every point solved from scratch;
//! * `λ-warm`  — warm starts along each λ chain, cold across γ
//!               (the pre-plane behavior);
//! * `γλ-warm` — the warm-start plane: the previous γ-chain's
//!               terminal α also seeds the next γ's first λ.
//!
//! Work is reported as summed `Solution::iterations` (coordinate
//! updates, comparable across losses) and summed
//! `Solution::sweep_entries` (gradient entries written — the cost
//! shrinking attacks).  `--quick` (CI) shrinks the problem and
//! asserts the structural claims: shrink-on writes fewer sweep
//! entries than shrink-off at fixed accuracy on the box losses, and
//! γλ-warm spends no more iterations than cold.
//!
//! Run: `cargo bench --bench table_solver [-- --quick]`

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use harness::{secs, sized, time_once, Snapshot, Table};
use liquid_svm::data::matrix::Matrix;
use liquid_svm::data::synth;
use liquid_svm::kernel::{GramBackend, KernelKind};
use liquid_svm::solver::{solve_dense, warm_vector, SolverKind, SolverParams};

struct Cell {
    iterations: usize,
    sweeps: u64,
    objective: f32,
    wall: Duration,
}

#[derive(Clone, Copy, PartialEq)]
enum WarmMode {
    Cold,
    Lambda,
    GammaLambda,
}

/// Walk the (γ, λ) grid under one warm mode, accumulating work.
fn run_grid(
    kind: SolverKind,
    grams: &[Matrix],
    y: &[f32],
    lambdas: &[f32],
    params: &SolverParams,
    mode: WarmMode,
) -> Cell {
    let mut iterations = 0usize;
    let mut sweeps = 0u64;
    let mut objective = 0.0f32;
    let (_, wall) = time_once(|| {
        let mut carry: Option<Vec<f32>> = None; // survives γ in GammaLambda mode
        for k in grams {
            let mut warm: Option<Vec<f32>> =
                if mode == WarmMode::GammaLambda { carry.take() } else { None };
            for &lambda in lambdas {
                let w = if mode == WarmMode::Cold { None } else { warm.as_deref() };
                let sol = solve_dense(kind, k, y, lambda, params, w);
                iterations += sol.iterations;
                sweeps += sol.sweep_entries;
                objective = sol.objective;
                warm = Some(warm_vector(kind, &sol, y));
            }
            carry = warm;
        }
    });
    Cell { iterations, sweeps, objective, wall }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = sized(260, 1200, 4000);
    let db = synth::banana_binary(n, 42);
    let dr = synth::sinc_hetero(n, 43);
    let gammas = [1.2f32, 1.0];
    let lambdas = [4e-3f32, 2e-3, 1e-3, 5e-4];
    let shrink_on = SolverParams { shrink_every: 200, ..SolverParams::default() };
    let shrink_off = SolverParams { shrink_every: 0, ..SolverParams::default() };

    let losses: [(&str, SolverKind, &Matrix, &[f32]); 4] = [
        ("hinge", SolverKind::Hinge { w: 0.5 }, &db.x, &db.y),
        ("ls", SolverKind::LeastSquares, &dr.x, &dr.y),
        ("quantile", SolverKind::Quantile { tau: 0.5 }, &dr.x, &dr.y),
        ("expectile", SolverKind::Expectile { tau: 0.8 }, &dr.x, &dr.y),
    ];

    println!("table_solver: n={n}, 2γ×{}λ grid, shrink_every=200 when on", lambdas.len());
    let table = Table::new(
        &["loss", "shrink", "warm", "iters", "sweep_entries", "time"],
        &[9, 6, 8, 10, 14, 8],
    );
    let mut snap = Snapshot::new("table_solver");

    for (name, kind, x, y) in losses {
        let grams: Vec<Matrix> = gammas
            .iter()
            .map(|&g| GramBackend::Blocked.gram(x, x, g, KernelKind::Gauss))
            .collect();
        let mut cells: Vec<(&str, &str, Cell)> = Vec::new();
        for (sname, params) in [("off", &shrink_off), ("on", &shrink_on)] {
            for (wname, mode) in [
                ("cold", WarmMode::Cold),
                ("λ", WarmMode::Lambda),
                ("γλ", WarmMode::GammaLambda),
            ] {
                let cell = run_grid(kind, &grams, y, &lambdas, params, mode);
                table.row(&[
                    name,
                    sname,
                    wname,
                    &cell.iterations.to_string(),
                    &cell.sweeps.to_string(),
                    &secs(cell.wall),
                ]);
                let wtag = match mode {
                    WarmMode::Cold => "cold",
                    WarmMode::Lambda => "lwarm",
                    WarmMode::GammaLambda => "glwarm",
                };
                snap.case(
                    &format!("{name}_shrink_{sname}_{wtag}"),
                    cell.wall,
                    cell.iterations as f64 / cell.wall.as_secs_f64().max(1e-9),
                    "iters/s",
                );
                cells.push((sname, wname, cell));
            }
        }
        let get = |s: &str, w: &str| {
            cells.iter().find(|(a, b, _)| *a == s && *b == w).map(|(_, _, c)| c).unwrap()
        };
        // structural claims, enforced in CI via --quick:
        // final objectives agree across every configuration (same ε-KKT)
        let base = get("off", "cold").objective;
        for (s, w, c) in &cells {
            assert!(
                (c.objective - base).abs() < 2e-2 * (1.0 + base.abs()),
                "{name} [{s}/{w}]: objective {} drifted from {base}",
                c.objective
            );
        }
        // the warm-start plane spends no more coordinate updates than
        // cold starts
        assert!(
            get("off", "γλ").iterations <= get("off", "cold").iterations,
            "{name}: γλ-warm slower than cold"
        );
        // shrinking writes fewer gradient entries on the box losses
        // (ls has no box; expectile shrink gains depend on scale)
        if quick && (name == "hinge" || name == "quantile") {
            assert!(
                get("on", "cold").sweeps < get("off", "cold").sweeps,
                "{name}: shrink-on did not reduce sweep work ({} vs {})",
                get("on", "cold").sweeps,
                get("off", "cold").sweeps
            );
        }
    }
    snap.write();
    println!("table_solver OK");
}
