//! Observability-plane bench: the cost of the instrumentation itself
//! (DESIGN.md §Observability).
//!
//! Two claims, both asserted:
//!
//! * **disabled spans are free** — with tracing off, `obs::span` is a
//!   relaxed atomic load plus a branch (no clock read, no allocation,
//!   no lock).  Measured over 10M call sites and asserted under a
//!   generous absolute bound, so a regression that sneaks a syscall or
//!   mutex into the disabled path fails the bench.
//! * **enabled tracing is cheap at phase granularity** — a fully
//!   traced small training run stays within 2× of the untraced run
//!   (in practice it is within noise: spans sit at solve/fill/fold
//!   boundaries, never inside per-coordinate loops).
//!
//! Runs in CI as `cargo bench --bench table_obs -- --quick`.

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use harness::{sized, time_once, Snapshot, Table};
use liquid_svm::data::synth;
use liquid_svm::obs;
use liquid_svm::prelude::*;

fn main() {
    let n = sized(200, 600, 1500);
    println!("\n=== observability overhead (train n={n}) ===\n");
    let mut snap = Snapshot::new("table_obs");
    let t = Table::new(&["case", "wall", "per-unit", "note"], &[22, 10, 14, 28]);

    // -- 1. disabled-span overhead ------------------------------------
    obs::set_enabled(false);
    obs::reset();
    let iters: u64 = 10_000_000;
    // warm-up (page in the code path)
    for _ in 0..10_000u64 {
        std::hint::black_box(obs::span("bench.disabled"));
    }
    let ((), wall_off) = time_once(|| {
        for _ in 0..iters {
            std::hint::black_box(obs::span("bench.disabled"));
        }
    });
    let ns_per_span = wall_off.as_nanos() as f64 / iters as f64;
    t.row(&[
        "disabled span x10M",
        &format!("{:.0}ms", wall_off.as_secs_f64() * 1e3),
        &format!("{ns_per_span:.1}ns"),
        "atomic load + branch",
    ]);
    snap.case("disabled_span", wall_off, iters as f64 / wall_off.as_secs_f64().max(1e-9), "spans/s");
    assert!(
        obs::phases().is_empty(),
        "disabled spans must not touch the phase table"
    );
    // generous absolute bound: a relaxed load + branch is single-digit
    // ns; 250ns catches a clock read, lock, or allocation sneaking in
    // while staying safe on oversubscribed CI boxes (debug builds are
    // slower across the board, so the bound scales there).
    let bound_ns = if cfg!(debug_assertions) { 2_500.0 } else { 250.0 };
    assert!(
        ns_per_span < bound_ns,
        "disabled span costs {ns_per_span:.1}ns (bound {bound_ns}ns) — the off path is no longer a single branch"
    );

    // -- 2. traced vs untraced training -------------------------------
    let train = synth::banana_binary(n, 77);
    let cfg = Config::default().folds(3);
    // warm-up run absorbs one-time costs (thread spin-up, allocator)
    let _ = svm_binary(&train, 0.5, &cfg).unwrap();

    let (_, t_plain) = time_once(|| svm_binary(&train, 0.5, &cfg).unwrap());

    obs::set_enabled(true);
    obs::reset();
    let (_, t_traced) = time_once(|| svm_binary(&train, 0.5, &cfg).unwrap());
    obs::set_enabled(false);
    let rows = obs::phases();
    assert!(!rows.is_empty(), "traced run recorded no phases");
    let spans_closed: u64 = rows.iter().map(|(_, s)| s.calls).sum();
    let ratio = t_traced.as_secs_f64() / t_plain.as_secs_f64().max(1e-9);

    t.row(&[
        "train untraced",
        &format!("{:.0}ms", t_plain.as_secs_f64() * 1e3),
        "-",
        "baseline",
    ]);
    t.row(&[
        "train traced",
        &format!("{:.0}ms", t_traced.as_secs_f64() * 1e3),
        &format!("x{ratio:.2}"),
        &format!("{} phases, {} spans", rows.len(), spans_closed),
    ]);
    snap.case("train_untraced", t_plain, n as f64 / t_plain.as_secs_f64().max(1e-9), "rows/s");
    snap.case("train_traced", t_traced, n as f64 / t_traced.as_secs_f64().max(1e-9), "rows/s");
    snap.case(
        "span_record",
        Duration::from_nanos(
            ((t_traced.as_secs_f64() - t_plain.as_secs_f64()).max(0.0) * 1e9) as u64,
        ),
        spans_closed as f64 / t_traced.as_secs_f64().max(1e-9),
        "spans/s",
    );
    // phase-granularity spans must not meaningfully slow training; 2x
    // leaves head-room for timer noise on tiny --quick problems.
    assert!(
        ratio < 2.0,
        "traced training {ratio:.2}x slower than untraced — spans are too hot"
    );
    obs::reset();
    snap.write();

    println!("\ntable_obs OK: disabled span {ns_per_span:.1}ns, traced/untraced x{ratio:.2}");
}
