"""L2 graph shape/numerics tests (the functions aot.py lowers)."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def rand(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


def test_cv_gram_is_symmetric_stack():
    x = rand(70, 6)
    gs = jnp.asarray([0.5, 1.0, 2.0], jnp.float32)
    k = np.asarray(model.cv_gram(x, gs))
    assert k.shape == (3, 70, 70)
    for i in range(3):
        np.testing.assert_allclose(k[i], k[i].T, rtol=1e-5, atol=1e-6)


def test_cross_gram_matches_ref():
    xv, xt = rand(30, 5), rand(50, 5)
    gs = jnp.asarray([0.7, 3.0], jnp.float32)
    np.testing.assert_allclose(
        np.asarray(model.cross_gram(xv, xt, gs)),
        np.asarray(ref.gram_rbf_multi(xv, xt, gs)), rtol=2e-5, atol=2e-6)


def test_val_predict_matches_composition():
    xv, xt = rand(20, 4), rand(35, 4)
    gs = jnp.asarray([0.5, 1.5], jnp.float32)
    alphas = rand(2, 35, 3)
    got = np.asarray(model.val_predict(xv, xt, alphas, gs))
    assert got.shape == (2, 20, 3)
    for i in range(2):
        want = np.asarray(ref.gram_rbf(xv, xt, float(gs[i]))) @ np.asarray(alphas[i])
        np.testing.assert_allclose(got[i], want, rtol=2e-4, atol=2e-4)


def test_predict_ls_shape():
    out = model.predict_ls(rand(11, 3), rand(17, 3), rand(17, 5), 1.0)
    assert out.shape == (11, 5)
