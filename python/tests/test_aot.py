"""AOT artifact tests: manifest consistency + HLO text round-trips."""

import json
import os

import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_fingerprint_is_stable():
    assert aot.input_fingerprint() == aot.input_fingerprint()


def test_bucket_lists_sane():
    for n, m, d in aot.GRAM_BUCKETS:
        assert n % 128 == 0 and m % 128 == 0 and d > 0
    for m, n, d in aot.PREDICT_BUCKETS:
        assert m % 128 == 0 and n % 128 == 0


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run make artifacts)")
def test_manifest_matches_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["gamma_chunk"] == aot.GAMMA_CHUNK
    for row in man["artifacts"]:
        path = os.path.join(ART, row["name"] + ".hlo.txt")
        assert os.path.exists(path), row["name"]
        head = open(path).read(200)
        assert "HloModule" in head


def test_build_entries_cover_buckets():
    entries, man = aot.build_entries()
    assert len(entries) == len(aot.GRAM_BUCKETS) + len(aot.PREDICT_BUCKETS)
    names = {e[0] for e in entries}
    assert len(names) == len(entries)  # unique artifact names


def test_hlo_text_lowering_smoke():
    import jax, jax.numpy as jnp
    low = jax.jit(lambda x: (x + 1.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(low)
    assert "HloModule" in text
