"""The project-invariant lint pass (scripts/check_invariants.py) must
hold on the checked-in tree, and its --self-test must prove it still
catches every seeded violation class (DESIGN.md §Static-analysis)."""

import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
CHECKER = os.path.join(REPO, "scripts", "check_invariants.py")


def run(*args):
    return subprocess.run(
        [sys.executable, CHECKER, *args],
        capture_output=True,
        text=True,
        cwd=REPO,
    )


def test_repo_satisfies_invariants():
    r = run()
    assert r.returncode == 0, f"invariant violations:\n{r.stdout}{r.stderr}"
    assert "OK: 6 invariants hold" in r.stdout


def test_checker_catches_seeded_violations():
    r = run("--self-test")
    assert r.returncode == 0, f"self-test broken:\n{r.stdout}{r.stderr}"
    assert "self-test OK" in r.stdout


def test_checker_fails_on_violating_tree(tmp_path):
    src = tmp_path / "rust" / "src" / "serve"
    src.mkdir(parents=True)
    (src / "mod.rs").write_text("use std::sync::Mutex;\n")
    (tmp_path / "DESIGN.md").write_text("")
    r = run("--root", str(tmp_path))
    assert r.returncode == 1
    assert "sync-shim" in r.stdout
