"""Hypothesis sweeps: randomized shapes/dtypes/scales for the Pallas
kernels against the jnp oracle (the property-based half of L1 testing)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import predict as pk
from compile.kernels import rbf, ref

SET = settings(max_examples=25, deadline=None)

dims = st.integers(min_value=1, max_value=40)
rows = st.integers(min_value=1, max_value=200)
gammas = st.floats(min_value=0.05, max_value=16.0)
scales = st.sampled_from([0.1, 1.0, 10.0])
dtypes = st.sampled_from([np.float32, np.float64])


def make(rng, m, d, scale, dtype):
    return jnp.asarray(rng.normal(scale=scale, size=(m, d)).astype(dtype))


@SET
@given(m=rows, n=rows, d=dims, g=gammas, scale=scales, dtype=dtypes,
       seed=st.integers(0, 2**31))
def test_gram_sweep(m, n, d, g, scale, dtype, seed):
    rng = np.random.default_rng(seed)
    x, y = make(rng, m, d, scale, dtype), make(rng, n, d, scale, dtype)
    got = np.asarray(rbf.gram(x, y, g))
    want = np.asarray(ref.gram_rbf(x.astype(jnp.float32),
                                   y.astype(jnp.float32), g))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)
    assert got.shape == (m, n)
    assert got.min() >= 0.0 and got.max() <= 1.0 + 1e-5


@SET
@given(m=rows, n=rows, d=dims, seed=st.integers(0, 2**31),
       g_count=st.integers(1, 12))
def test_gram_multi_sweep(m, n, d, seed, g_count):
    rng = np.random.default_rng(seed)
    x, y = make(rng, m, d, 1.0, np.float32), make(rng, n, d, 1.0, np.float32)
    gs = jnp.asarray(np.geomspace(0.1, 10.0, g_count), jnp.float32)
    got = np.asarray(rbf.gram_multi(x, y, gs))
    want = np.asarray(ref.gram_rbf_multi(x, y, gs))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)
    assert got.shape == (g_count, m, n)


@SET
@given(m=st.integers(1, 80), n=st.integers(1, 300), d=dims,
       t=st.integers(1, 8), g=gammas, seed=st.integers(0, 2**31))
def test_predict_sweep(m, n, d, t, g, seed):
    rng = np.random.default_rng(seed)
    x, sv = make(rng, m, d, 1.0, np.float32), make(rng, n, d, 1.0, np.float32)
    a = make(rng, n, t, 1.0, np.float32)
    got = np.asarray(pk.predict(x, sv, a, g))
    want = np.asarray(ref.predict(x, sv, a, g))
    np.testing.assert_allclose(got, want, rtol=4e-4, atol=4e-4)
