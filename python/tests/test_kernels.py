"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import predict as pk
from compile.kernels import rbf, ref

RNG = np.random.default_rng(12345)


def rand(*shape, scale=1.0):
    return jnp.asarray(RNG.normal(scale=scale, size=shape), jnp.float32)


def assert_close(a, b, rtol=2e-5, atol=2e-6):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------- gram


@pytest.mark.parametrize("m,n,d", [(4, 4, 2), (128, 128, 16), (37, 53, 9),
                                   (129, 127, 3), (200, 1, 5), (1, 200, 5)])
@pytest.mark.parametrize("gamma", [0.25, 1.0, 4.0])
def test_gram_rbf_matches_ref(m, n, d, gamma):
    x, y = rand(m, d), rand(n, d)
    assert_close(rbf.gram(x, y, gamma), ref.gram_rbf(x, y, gamma))


@pytest.mark.parametrize("m,n,d", [(64, 64, 8), (37, 53, 9), (130, 70, 21)])
def test_gram_laplace_matches_ref(m, n, d):
    x, y = rand(m, d), rand(n, d)
    # sqrt near 0 is non-smooth: slightly looser atol on the diagonal-ish
    # entries where d2 ~ 0 and round-off flips across the clamp.
    assert_close(rbf.gram(x, y, 0.7, laplace=True),
                 ref.gram_laplace(x, y, 0.7), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("g_count", [1, 3, 10])
def test_gram_multi_matches_ref(g_count):
    x, y = rand(90, 7), rand(110, 7)
    gammas = jnp.asarray(np.geomspace(0.1, 8.0, g_count), jnp.float32)
    assert_close(rbf.gram_multi(x, y, gammas), ref.gram_rbf_multi(x, y, gammas))


def test_gram_symmetric_unit_diagonal():
    x = rand(77, 5)
    k = np.asarray(rbf.gram(x, x, 1.7))
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.diag(k), 1.0, rtol=1e-5, atol=1e-5)


def test_gram_values_in_unit_interval():
    x, y = rand(60, 4, scale=3.0), rand(80, 4, scale=3.0)
    k = np.asarray(rbf.gram(x, y, 0.3))
    assert k.min() >= 0.0 and k.max() <= 1.0 + 1e-6


def test_gram_block_size_invariance():
    x, y = rand(100, 6), rand(140, 6)
    a = rbf.gram(x, y, 1.1, block=32)
    b = rbf.gram(x, y, 1.1, block=128)
    assert_close(a, b)


def test_multi_gamma_consistent_with_single():
    x, y = rand(50, 8), rand(66, 8)
    gammas = jnp.asarray([0.5, 2.0], jnp.float32)
    multi = rbf.gram_multi(x, y, gammas)
    for i, g in enumerate([0.5, 2.0]):
        assert_close(multi[i], rbf.gram(x, y, g))


def test_libsvm_parameterization_bridge():
    # liquidSVM k = exp(-d2/g^2); libsvm k = exp(-g_lib*d2).
    # g = 1/sqrt(g_lib) must give identical matrices.
    x, y = rand(40, 5), rand(30, 5)
    g_lib = 0.125
    ours = rbf.gram(x, y, 1.0 / np.sqrt(g_lib))
    theirs = jnp.exp(-g_lib * ref.sq_dists(x, y))
    assert_close(ours, theirs)


# ------------------------------------------------------------- predict


@pytest.mark.parametrize("m,n,d,t", [(64, 64, 8, 1), (100, 130, 5, 4),
                                     (129, 257, 12, 8), (1, 50, 3, 2)])
def test_predict_matches_ref(m, n, d, t):
    x, sv, a = rand(m, d), rand(n, d), rand(n, t)
    assert_close(pk.predict(x, sv, a, 1.3), ref.predict(x, sv, a, 1.3),
                 rtol=2e-4, atol=2e-5)


def test_predict_zero_alpha_is_zero():
    x, sv = rand(30, 4), rand(40, 4)
    a = jnp.zeros((40, 2), jnp.float32)
    out = np.asarray(pk.predict(x, sv, a, 1.0))
    assert np.all(out == 0.0)


def test_predict_linear_in_alpha():
    x, sv, a = rand(30, 4), rand(40, 4), rand(40, 3)
    one = np.asarray(pk.predict(x, sv, a, 0.9))
    two = np.asarray(pk.predict(x, sv, 2.0 * a, 0.9))
    np.testing.assert_allclose(two, 2.0 * one, rtol=2e-4, atol=2e-5)


def test_predict_accumulation_over_sv_blocks():
    # n spanning several 128-blocks exercises the @pl.when init +
    # accumulate reduction path.
    x, sv, a = rand(10, 6), rand(400, 6), rand(400, 2)
    assert_close(pk.predict(x, sv, a, 1.5), ref.predict(x, sv, a, 1.5),
                 rtol=2e-4, atol=2e-5)
