"""L2: the JAX compute graphs that get AOT-lowered for the Rust runtime.

liquidSVM has no neural "model"; its L2-equivalents are the dense linear
algebra blocks of the training/selection/test cycle, each calling the L1
Pallas kernels:

  * ``cv_gram``      — multi-gamma Gram matrix over a fold (training-phase
                       hot spot; one distance computation serves the whole
                       gamma grid).
  * ``predict_ls``   — decision values for T models sharing support
                       vectors (test phase / validation-error evaluation).
  * ``val_predict``  — validation-fold decision values for ALL gammas at
                       once: [G,mv,n] Gram x [G,n,T] coefficients, the
                       selection-phase hot spot.

Every function is shape-static; aot.py lowers one HLO artifact per shape
bucket and the Rust side pads its data to the nearest bucket.
"""

import jax
import jax.numpy as jnp

from .kernels import predict as pk
from .kernels import rbf


def cv_gram(x, gammas):
    """Symmetric training Gram stack: x [n,d], gammas [G] -> [G,n,n]."""
    return rbf.gram_multi(x, x, gammas)


def cross_gram(x, y, gammas):
    """Rectangular Gram stack (validation rows vs training columns)."""
    return rbf.gram_multi(x, y, gammas)


def predict_ls(x, sv, alpha, gamma):
    """Fused test-phase prediction: [m,d],[n,d],[n,T] -> [m,T]."""
    return pk.predict(x, sv, alpha, gamma)


def val_predict(xv, xt, alphas, gammas):
    """Selection-phase: decision values on a validation fold for the whole
    gamma grid in one shot.

    xv: [mv,d] validation fold, xt: [n,d] training fold,
    alphas: [G,n,T] coefficients (T = lambda grid x tasks columns),
    gammas: [G] -> [G,mv,T].
    """
    k = rbf.gram_multi(xv, xt, gammas)            # [G,mv,n]
    return jnp.einsum("gmn,gnt->gmt", k, alphas)
