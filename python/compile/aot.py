"""AOT: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
xla_extension 0.5.1 bundled with the published ``xla`` crate rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

One artifact per shape bucket; the Rust side zero-pads its data up to the
nearest bucket (padding is exact for every graph here, see kernels/*.py)
and slices the result.  ``manifest.json`` records name -> shapes so the
Rust artifact registry can pick buckets without parsing HLO.

Run: ``cd python && python -m compile.aot --out ../artifacts``
(re-running is cheap and idempotent; the Makefile skips it when inputs
are unchanged).
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Number of gammas baked into the multi-gamma Gram artifacts.  Matches
# the paper's default 10x10 grid; larger grids are tiled by the Rust
# side in chunks of GAMMA_CHUNK.
GAMMA_CHUNK = 10
# Prediction artifacts: coefficient columns per call (lambda grid slots
# or tasks); Rust pads/tiles to this.
T_COLS = 8

# (rows, cols, dim) buckets for Gram artifacts — sized for the paper's
# cell regime (fine cells <= 2000 samples, d up to 256 for WEBSPAM-sim).
GRAM_BUCKETS = [
    (256, 256, 16),
    (256, 256, 64),
    (1024, 1024, 16),
    (1024, 1024, 64),
    (1024, 1024, 256),
    (2048, 2048, 16),
    (2048, 2048, 64),
    (2048, 2048, 256),
]
# (m_test, n_sv, dim) buckets for the fused predict artifact.
PREDICT_BUCKETS = [
    (1024, 1024, 16),
    (1024, 1024, 64),
    (1024, 2048, 16),
    (1024, 2048, 64),
    (1024, 1024, 256),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_entries():
    """(name, lowered) pairs + manifest rows for every artifact."""
    entries = []
    manifest = {"gamma_chunk": GAMMA_CHUNK, "t_cols": T_COLS, "artifacts": []}

    for n, m, d in GRAM_BUCKETS:
        name = f"gram10_{n}x{m}x{d}"
        low = jax.jit(model.cross_gram).lower(f32(n, d), f32(m, d), f32(GAMMA_CHUNK))
        entries.append((name, low))
        manifest["artifacts"].append(
            {"name": name, "op": "gram_multi", "rows": n, "cols": m, "dim": d,
             "gammas": GAMMA_CHUNK}
        )

    for m, n, d in PREDICT_BUCKETS:
        name = f"predict_{m}x{n}x{d}x{T_COLS}"
        low = jax.jit(model.predict_ls).lower(
            f32(m, d), f32(n, d), f32(n, T_COLS), f32()
        )
        entries.append((name, low))
        manifest["artifacts"].append(
            {"name": name, "op": "predict", "rows": m, "cols": n, "dim": d,
             "t_cols": T_COLS}
        )

    return entries, manifest


def input_fingerprint() -> str:
    """Hash of the compile-path sources, for Makefile-style skipping."""
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    stamp = os.path.join(args.out, "stamp.txt")
    fp = input_fingerprint()
    if not args.force and os.path.exists(stamp):
        with open(stamp) as f:
            if f.read().strip() == fp:
                print("artifacts up to date")
                return

    entries, manifest = build_entries()
    for name, low in entries:
        text = to_hlo_text(low)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # TSV twin of the manifest: the Rust side has no JSON dependency in
    # this offline image, so it reads this instead.
    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        f.write(f"gamma_chunk\t{GAMMA_CHUNK}\tt_cols\t{T_COLS}\n")
        for row in manifest["artifacts"]:
            f.write(
                "\t".join(
                    str(v)
                    for v in (
                        row["name"], row["op"], row["rows"], row["cols"],
                        row["dim"], row.get("gammas", 0), row.get("t_cols", 0),
                    )
                )
                + "\n"
            )
    with open(stamp, "w") as f:
        f.write(fp)
    print(f"{len(entries)} artifacts -> {args.out}")


if __name__ == "__main__":
    main()
