"""L1 Pallas kernel: fused SVM prediction.

Decision values for T models that share a support-vector set:

    out[i,t] = sum_j k_gamma(x_i, sv_j) * alpha[j,t]

The kernel tile k(x_block, sv_block) is computed exactly as in rbf.py
(MXU matmul + fused exponential epilogue) and immediately contracted
against the coefficient block — the Gram tile lives only in VMEM and is
never materialized in HBM.  The sv/grid axis is the innermost
(sequential) grid dimension, so the output block accumulates across it
(classic Pallas reduction pattern with an @pl.when(j == 0) init).

This fuses liquidSVM's "evaluating the SVM models on the test data"
routine (paper §3, SIMD/CUDA accelerated) into a single pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import rbf


def _predict_kernel(x_ref, sv_ref, a_ref, g_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d2 = rbf._tile_sq_dists(x_ref[...], sv_ref[...])     # [bm,bn]
    g = g_ref[0]
    k = jnp.exp(-d2 / (g * g))
    o_ref[...] += jnp.dot(k, a_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def predict(x, sv, alpha, gamma, *, block=rbf.DEFAULT_BLOCK):
    """x: [m,d], sv: [n,d], alpha: [n,T], gamma scalar -> [m,T] float32.

    Zero-padding sv/alpha rows is exact (padded alpha rows are zero, so
    their kernel values contribute nothing), hence arbitrary shapes work.
    """
    m, d = x.shape
    n = sv.shape[0]
    t = alpha.shape[1]
    mp, np_ = rbf._ceil_to(m, block), rbf._ceil_to(n, block)
    xp = rbf._pad_to(x.astype(jnp.float32), mp)
    svp = rbf._pad_to(sv.astype(jnp.float32), np_)
    ap = rbf._pad_to(alpha.astype(jnp.float32), np_)
    g = jnp.asarray(gamma, jnp.float32).reshape((1,))
    out = pl.pallas_call(
        _predict_kernel,
        grid=(mp // block, np_ // block),
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block, t), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block, t), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, t), jnp.float32),
        interpret=rbf.INTERPRET,
    )(xp, svp, ap, g)
    return out[:m]
