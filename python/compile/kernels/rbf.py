"""L1 Pallas kernels: tiled Gram-matrix computation.

This is the liquidSVM hot spot ("routines for computing the kernel
matrices ... are parallelized ... Cuda implementations ... exist",
paper §3) re-thought for a TPU-shaped accelerator:

  * the pairwise squared-distance tile is `||x||^2 + ||y||^2 - 2 x.y^T`,
    i.e. one MXU matmul (bf16/f32) plus two rank-1 broadcasts;
  * BlockSpec tiles X rows and Y rows into VMEM (the scratchpad), one
    (block_m x block_n) Gram tile per grid step — this replaces the
    paper's SSE/AVX inner loops and CUDA threadblocks;
  * the exp(-d2/gamma^2) epilogue is fused in-register, so the distance
    tile never round-trips through HBM;
  * the multi-gamma variant reuses one distance tile for the WHOLE gamma
    grid (the paper's kernel-matrix-reuse CV trick): gamma enters as a
    [G] vector and the epilogue broadcasts over it.

Kernels are lowered with interpret=True (CPU image; real-TPU lowering
emits Mosaic custom-calls the CPU PJRT plugin cannot execute).  All
public wrappers pad inputs to block multiples and slice the result, so
any (m, n, d) works.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128

# Set by aot.py / tests; interpret=True is mandatory on this image.
INTERPRET = True


def _pad_to(a, rows, cols=None):
    """Zero-pad a 2-d array up to (rows, cols)."""
    pr = rows - a.shape[0]
    pc = 0 if cols is None else cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def _ceil_to(x, b):
    return ((x + b - 1) // b) * b


def _tile_sq_dists(x, y):
    """Distance tile: [bm,d] x [bn,d] -> [bm,bn], MXU matmul + broadcasts."""
    xn = jnp.sum(x * x, axis=1, keepdims=True)          # [bm,1]
    yn = jnp.sum(y * y, axis=1, keepdims=True)          # [bn,1]
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    return jnp.maximum(xn + yn.T - 2.0 * xy, 0.0)


def _gram_kernel(x_ref, y_ref, g_ref, o_ref, *, laplace):
    d2 = _tile_sq_dists(x_ref[...], y_ref[...])
    g = g_ref[0]
    if laplace:
        o_ref[...] = jnp.exp(-jnp.sqrt(d2) / g)
    else:
        o_ref[...] = jnp.exp(-d2 / (g * g))


def _gram_multi_kernel(x_ref, y_ref, g_ref, o_ref):
    d2 = _tile_sq_dists(x_ref[...], y_ref[...])          # [bm,bn]
    g2 = g_ref[...] * g_ref[...]                         # [G]
    # one distance tile, G exponentiations — the CV reuse trick fused.
    o_ref[...] = jnp.exp(-d2[None, :, :] / g2[:, None, None])


@functools.partial(jax.jit, static_argnames=("block", "laplace"))
def gram(x, y, gamma, *, block=DEFAULT_BLOCK, laplace=False):
    """Gram matrix K[i,j] = k_gamma(x_i, y_j), liquidSVM parameterization.

    x: [m,d], y: [n,d], gamma: scalar -> [m,n] float32.
    """
    m, d = x.shape
    n = y.shape[0]
    mp, np_ = _ceil_to(m, block), _ceil_to(n, block)
    xp = _pad_to(x.astype(jnp.float32), mp)
    yp = _pad_to(y.astype(jnp.float32), np_)
    g = jnp.asarray(gamma, jnp.float32).reshape((1,))
    out = pl.pallas_call(
        functools.partial(_gram_kernel, laplace=laplace),
        grid=(mp // block, np_ // block),
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=INTERPRET,
    )(xp, yp, g)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("block",))
def gram_multi(x, y, gammas, *, block=DEFAULT_BLOCK):
    """Gram matrices for a whole gamma grid: [G] -> [G,m,n] float32.

    One distance tile per grid step serves all G gammas — the Pallas
    form of liquidSVM's kernel-matrix reuse across the CV grid.
    """
    m, d = x.shape
    n = y.shape[0]
    G = gammas.shape[0]
    mp, np_ = _ceil_to(m, block), _ceil_to(n, block)
    xp = _pad_to(x.astype(jnp.float32), mp)
    yp = _pad_to(y.astype(jnp.float32), np_)
    out = pl.pallas_call(
        _gram_multi_kernel,
        grid=(mp // block, np_ // block),
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block, d), lambda i, j: (j, 0)),
            pl.BlockSpec((G,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((G, block, block), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, mp, np_), jnp.float32),
        interpret=INTERPRET,
    )(xp, yp, gammas.astype(jnp.float32))
    return out[:, :m, :n]
