"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package is validated against the functions here by pytest (exact shapes)
and hypothesis (randomized shape/dtype sweeps).

liquidSVM kernel parameterization (paper, Table 5, last row):

    k_gauss(u, v)   = exp(-||u - v||^2 / gamma^2)
    k_laplace(u, v) = exp(-||u - v||   / gamma)      ("Poisson" kernel)

Note the gamma**2 in the denominator for the Gaussian — this differs from
the libsvm convention exp(-gamma*||u-v||^2); the Rust grid code converts
between the two when running on the "libsvm grid".
"""

import jax.numpy as jnp


def sq_dists(x, y):
    """Pairwise squared Euclidean distances, [m,d] x [n,d] -> [m,n].

    Computed the same way the tiled kernel computes it
    (||x||^2 + ||y||^2 - 2 x.y) so tolerance comparisons are honest, then
    clamped at zero against negative round-off.
    """
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    yn = jnp.sum(y * y, axis=1, keepdims=True)
    d2 = xn + yn.T - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def gram_rbf(x, y, gamma):
    """Gaussian RBF Gram matrix, liquidSVM parameterization."""
    return jnp.exp(-sq_dists(x, y) / (gamma * gamma))


def gram_laplace(x, y, gamma):
    """Laplacian ("Poisson") Gram matrix."""
    return jnp.exp(-jnp.sqrt(sq_dists(x, y)) / gamma)


def gram_rbf_multi(x, y, gammas):
    """Gram matrices for a vector of gammas: [G] -> [G,m,n].

    This is the CV hot path: one distance matrix reused for the whole
    gamma grid (the paper's "the required kernel matrices may be
    re-used").
    """
    d2 = sq_dists(x, y)
    g2 = (gammas * gammas)[:, None, None]
    return jnp.exp(-d2[None, :, :] / g2)


def predict(x, sv, alpha, gamma):
    """Decision values of T models sharing support vectors.

    x: [m,d] test points, sv: [n,d] support vectors, alpha: [n,T]
    coefficient columns (one per model/task), gamma scalar -> [m,T].
    """
    return gram_rbf(x, sv, gamma) @ alpha
