# L1: Pallas kernels for liquidSVM's compute hot-spots
# (Gram matrices + fused prediction), validated against ref.py.
from . import predict, rbf, ref  # noqa: F401
