#!/usr/bin/env bash
# Regenerate the committed bench baselines under rust/benches/snapshots/.
#
# The committed files start life as structure-only seeds ("seed": true,
# empty cases) so scripts/bench_diff.py has the filenames to compare
# against without anyone pretending a number was measured.  Running
# this script on a real machine replaces them with honest measurements
# (the harness stamps cpu count, git rev, and scale into each file);
# commit the result and bench_diff's >2x regression gate arms itself.
#
# Usage:
#   scripts/refresh_snapshots.sh            # all benches, smoke scale
#   scripts/refresh_snapshots.sh --full     # full scale (slow; hours)
#   scripts/refresh_snapshots.sh table14_simd table_sparse
#
# Scale notes: smoke (--quick) is what CI runs and is the right
# baseline for the CI diff; --full matches the paper's table sizes.

set -euo pipefail
cd "$(dirname "$0")/.."

SCALE_ARG="--quick"
BENCHES=()
for arg in "$@"; do
  case "$arg" in
    --full) SCALE_ARG="" ;;
    --*) echo "unknown flag: $arg" >&2; exit 2 ;;
    *) BENCHES+=("$arg") ;;
  esac
done

if [ ${#BENCHES[@]} -eq 0 ]; then
  # every [[bench]] target in the manifest
  mapfile -t BENCHES < <(sed -n 's/^name = "\(table[^"]*\)"/\1/p' rust/Cargo.toml)
fi

OUT="$(pwd)/rust/benches/snapshots"
mkdir -p "$OUT"

for b in "${BENCHES[@]}"; do
  echo "=== $b ==="
  if [ -n "$SCALE_ARG" ]; then
    (cd rust && BENCH_OUT_DIR="$OUT" cargo bench --bench "$b" -- "$SCALE_ARG")
  else
    (cd rust && BENCH_OUT_DIR="$OUT" BENCH_SCALE=full cargo bench --bench "$b")
  fi
done

echo
echo "snapshots refreshed under rust/benches/snapshots/ — review and commit:"
git -C . status --short rust/benches/snapshots/ || true
