#!/usr/bin/env python3
"""Project-invariant lint pass for liquid_svm (DESIGN.md §Static-analysis).

Six whole-project invariants that rustc and clippy cannot see, checked
with nothing but the Python standard library so the pass runs in any
container (no Rust toolchain required) and in CI's `invariants` job:

  1. metrics    — every `pub static NAME: Counter` in metrics/counters.rs
                  is registered exactly once in obs/registry.rs, and every
                  `liquidsvm_*` exposition name in non-test code is
                  defined at exactly one site (no duplicate names across
                  the registry and the serve endpoint).
  2. spans      — every `obs::span("name")` in non-test code appears
                  backticked in DESIGN.md (the span-name contract);
                  `test.*` names are reserved for unit tests.
  3. determinism— no wall-clock (`SystemTime::now`) or ambient RNG
                  (`thread_rng`, `rand::random`, `from_entropy`) in the
                  deterministic paths: solver/, kernel/, cv/, persist.
  4. sync-shim  — no `std::sync` import outside src/sync.rs: every
                  concurrency seam must go through the loom-checkable
                  `crate::sync` shim (telemetry uses its `static_atomic`
                  carve-out, which is still inside sync.rs).
  5. clamp      — every squared-distance site using the
                  ‖x‖²+‖y‖²−2⟨x,y⟩ cancellation form clamps negative
                  rounding residue at the source (`.max(0.0)` on the
                  same expression), so no kernel ever sees d² < 0.
  6. serve-spawn— no `thread::spawn` / `thread::Builder` in src/serve/
                  outside eventloop.rs: the serve plane is event-driven
                  (no thread-per-connection); every serve thread comes
                  from the reactor/worker bootstrap in eventloop.rs.

`--self-test` seeds one violation of each class into a temp tree and
asserts the checker catches it (and that commented-out decoys do NOT
trip it); python/tests/test_invariants.py runs both modes.

Exit status: 0 clean, 1 violations found, 2 self-test failure.
"""

from __future__ import annotations

import argparse
import re
import sys
import tempfile
from pathlib import Path

# ----------------------------------------------------------------- helpers


def rust_files(src: Path) -> list[Path]:
    return sorted(src.rglob("*.rs"))


def strip_tests(text: str) -> str:
    """Drop everything from a trailing `#[cfg(test)] mod tests` on.

    The repo convention keeps the test module last in the file, so
    truncating at the attribute is exact; if code ever follows a test
    module this stays conservative (it checks less, never wrongly
    flags more).
    """
    lines = text.splitlines()
    for i, line in enumerate(lines):
        if line.strip().startswith("#[cfg(test)]"):
            follow = "\n".join(lines[i + 1 : i + 4])
            if re.search(r"\bmod\s+\w+", follow):
                return "\n".join(lines[:i])
    return text


def code_lines(text: str):
    """Yield (1-based lineno, comment-stripped line) for code lines.

    Whole-line comments (`//`, `///`, `//!`) are skipped and trailing
    `//` comments dropped — naive about `//` inside string literals,
    which the checked patterns never contain.
    """
    for i, raw in enumerate(text.splitlines(), start=1):
        stripped = raw.strip()
        if not stripped or stripped.startswith("//"):
            continue
        yield i, raw.split("//")[0]


def rel(path: Path, root: Path) -> str:
    try:
        return str(path.relative_to(root))
    except ValueError:
        return str(path)


# ------------------------------------------------------------- the checks


def check_metrics(root: Path) -> list[str]:
    """Invariant 1: counters registered exactly once; names unique."""
    src = root / "rust" / "src"
    out: list[str] = []

    counters_rs = src / "metrics" / "counters.rs"
    registry_rs = src / "obs" / "registry.rs"
    if not counters_rs.is_file() or not registry_rs.is_file():
        return [f"metrics: missing {rel(counters_rs, root)} or {rel(registry_rs, root)}"]

    statics = re.findall(
        r"^pub static (\w+): Counter", counters_rs.read_text(), re.MULTILINE
    )
    registry = strip_tests(registry_rs.read_text())
    for name in statics:
        n = len(re.findall(rf"\bcounters::{name}\b", registry))
        if n != 1:
            out.append(
                f"metrics: {rel(counters_rs, root)}: static `{name}` is "
                f"registered {n} times in obs/registry.rs (want exactly 1)"
            )

    # every liquidsvm_* exposition name is defined at exactly one site
    sites: dict[str, list[str]] = {}
    for path in rust_files(src):
        body = strip_tests(path.read_text())
        for lineno, line in code_lines(body):
            for name in re.findall(r'"(liquidsvm_\w+)"', line):
                sites.setdefault(name, []).append(f"{rel(path, root)}:{lineno}")
    for name, where in sorted(sites.items()):
        if len(where) != 1:
            out.append(
                f"metrics: exposition name `{name}` defined at "
                f"{len(where)} sites (want 1): {', '.join(where)}"
            )
    return out


def check_spans(root: Path) -> list[str]:
    """Invariant 2: span names live in DESIGN.md's span contract."""
    src = root / "rust" / "src"
    design_path = root / "DESIGN.md"
    if not design_path.is_file():
        return ["spans: DESIGN.md not found"]
    design = design_path.read_text()
    out = []
    for path in rust_files(src):
        body = strip_tests(path.read_text())
        for lineno, line in code_lines(body):
            for name in re.findall(r'\bspan(?:_slow)?\(\s*"([^"]+)"', line):
                if name.startswith("test."):
                    out.append(
                        f"spans: {rel(path, root)}:{lineno}: `test.*` span "
                        f"`{name}` outside a #[cfg(test)] module"
                    )
                elif f"`{name}`" not in design:
                    out.append(
                        f"spans: {rel(path, root)}:{lineno}: span `{name}` "
                        f"is not documented (backticked) in DESIGN.md"
                    )
    return out


DETERMINISM_TOKENS = ("SystemTime::now", "thread_rng", "rand::random", "from_entropy")


def deterministic_paths(root: Path) -> list[Path]:
    src = root / "rust" / "src"
    paths: list[Path] = []
    for sub in ("solver", "kernel", "cv"):
        d = src / sub
        if d.is_dir():
            paths.extend(rust_files(d))
    persist = src / "coordinator" / "persist.rs"
    if persist.is_file():
        paths.append(persist)
    return paths


def check_determinism(root: Path) -> list[str]:
    """Invariant 3: no wall clock / ambient RNG in deterministic paths."""
    out = []
    for path in deterministic_paths(root):
        # test modules count too: deterministic-path tests must not
        # smuggle in wall-clock either, so scan the full file
        for lineno, line in code_lines(path.read_text()):
            for tok in DETERMINISM_TOKENS:
                if tok in line:
                    out.append(
                        f"determinism: {rel(path, root)}:{lineno}: `{tok}` "
                        f"in a deterministic path (solver/kernel/cv/persist)"
                    )
    return out


def check_sync_imports(root: Path) -> list[str]:
    """Invariant 4: `std::sync` only inside the src/sync.rs shim."""
    src = root / "rust" / "src"
    out = []
    for path in rust_files(src):
        if path == src / "sync.rs":
            continue
        for lineno, line in code_lines(path.read_text()):
            if "std::sync" in line:
                out.append(
                    f"sync-shim: {rel(path, root)}:{lineno}: raw `std::sync` "
                    f"use outside src/sync.rs — route it through crate::sync "
                    f"so loom can model it (or sync.rs §static_atomic)"
                )
    return out


CANCELLATION = re.compile(r"-\s*2\.0\s*\*")


def clamp_paths(root: Path) -> list[Path]:
    src = root / "rust" / "src"
    paths = []
    kernel = src / "kernel"
    if kernel.is_dir():
        paths.extend(rust_files(kernel))
    matrix = src / "data" / "matrix.rs"
    if matrix.is_file():
        paths.append(matrix)
    return paths


def check_clamp(root: Path) -> list[str]:
    """Invariant 5: clamp-at-source on every cancellation-form d²."""
    out = []
    for path in clamp_paths(root):
        for lineno, line in code_lines(path.read_text()):
            if CANCELLATION.search(line) and ".max(0.0)" not in line:
                out.append(
                    f"clamp: {rel(path, root)}:{lineno}: cancellation-form "
                    f"squared distance without `.max(0.0)` on the same "
                    f"expression — rounding can make it negative"
                )
    return out


def check_serve_spawn(root: Path) -> list[str]:
    """Invariant 6: the serve plane never spawns per-connection
    threads — serve/eventloop.rs is the single spawn site."""
    serve = root / "rust" / "src" / "serve"
    if not serve.is_dir():
        return []
    out = []
    for path in rust_files(serve):
        if path.name == "eventloop.rs":
            continue
        body = strip_tests(path.read_text())
        for lineno, line in code_lines(body):
            if re.search(r"\bthread::(spawn|Builder)\b", line):
                out.append(
                    f"serve-spawn: {rel(path, root)}:{lineno}: thread spawn "
                    f"in serve/ outside eventloop.rs — the serve plane is "
                    f"event-driven (10k conns must not mean 10k threads); "
                    f"all serve threads come from the bootstrap in "
                    f"serve/eventloop.rs"
                )
    return out


CHECKS = [
    ("metrics", check_metrics),
    ("spans", check_spans),
    ("determinism", check_determinism),
    ("sync-shim", check_sync_imports),
    ("clamp", check_clamp),
    ("serve-spawn", check_serve_spawn),
]


def run_checks(root: Path) -> list[str]:
    findings: list[str] = []
    for _, fn in CHECKS:
        findings.extend(fn(root))
    return findings


# ------------------------------------------------------------- self-test


def write(path: Path, text: str) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text)


def self_test() -> int:
    """Seed one violation per class; assert each is caught and that
    commented-out decoys are not."""
    failures = []
    with tempfile.TemporaryDirectory(prefix="liquidsvm_inv_") as tmp:
        root = Path(tmp)
        src = root / "rust" / "src"

        # class 1a: a counter static never registered;
        # class 1b: an exposition name defined twice
        write(
            src / "metrics" / "counters.rs",
            "pub static ORPHAN_COUNTER: Counter = Counter::new();\n",
        )
        write(
            src / "obs" / "registry.rs",
            'r.register_counter("liquidsvm_dup", "a", &x);\n'
            'r.register_counter("liquidsvm_dup", "b", &y);\n'
            "#[cfg(test)]\nmod tests {\n"
            '    // names in tests are exempt: "liquidsvm_dup" again\n'
            '    const T: &str = "liquidsvm_test_only";\n'
            "}\n",
        )
        # class 2: an undocumented span (plus a commented decoy that
        # must NOT be flagged)
        write(
            src / "coordinator" / "driver.rs",
            '// let s = obs::span("commented.out");\n'
            'let _s = obs::span("mystery.phase");\n',
        )
        write(root / "DESIGN.md", "Spans: `train`, `predict`.\n")
        # class 3: wall clock in a deterministic path
        write(
            src / "solver" / "mod.rs",
            "// SystemTime::now in a comment is fine\n"
            "let t = std::time::SystemTime::now();\n",
        )
        # class 4: raw std::sync outside the shim
        write(
            src / "serve" / "mod.rs",
            "// use std::sync::Mutex; (decoy comment)\n"
            "use std::sync::Mutex;\n",
        )
        # class 5: unclamped cancellation-form distance
        write(
            src / "kernel" / "backend.rs",
            "let good = (xn + yn - 2.0 * dot).max(0.0);\n"
            "let bad = xn + yn - 2.0 * dot;\n",
        )
        # class 6: a per-connection thread spawned in serve/ (the
        # commented decoy must NOT be flagged; eventloop.rs is exempt)
        write(
            src / "serve" / "worker.rs",
            "// std::thread::spawn in a comment is fine\n"
            "std::thread::spawn(|| handle_conn(stream));\n",
        )
        write(
            src / "serve" / "eventloop.rs",
            "let h = std::thread::Builder::new().spawn(run_reactor);\n",
        )

        expected = {
            "metrics: .*`ORPHAN_COUNTER` is registered 0 times": check_metrics,
            "metrics: .*`liquidsvm_dup` defined at 2 sites": check_metrics,
            "spans: .*`mystery.phase`": check_spans,
            "determinism: .*SystemTime::now": check_determinism,
            "sync-shim: .*serve/mod.rs:2": check_sync_imports,
            "clamp: .*backend.rs:2": check_clamp,
            r"serve-spawn: .*serve/worker.rs:2": check_serve_spawn,
        }
        for pattern, fn in expected.items():
            hits = fn(root)
            if not any(re.search(pattern, h) for h in hits):
                failures.append(f"self-test: /{pattern}/ not caught; got {hits}")

        # false-positive guards: decoys in comments / test modules
        for fn, decoy in [
            (check_spans, "commented.out"),
            (check_sync_imports, "serve/mod.rs:1"),
            (check_determinism, "solver/mod.rs:1"),
            (check_metrics, "liquidsvm_test_only"),
            (check_clamp, "backend.rs:1"),
            (check_serve_spawn, "worker.rs:1"),
            (check_serve_spawn, "eventloop.rs:1"),
        ]:
            if any(decoy in h for h in fn(root)):
                failures.append(f"self-test: decoy `{decoy}` wrongly flagged")

    if failures:
        print("\n".join(failures))
        print(f"SELF-TEST FAILED ({len(failures)} problems)")
        return 2
    print(f"self-test OK: all {len(CHECKS)} violation classes caught, decoys ignored")
    return 0


# ------------------------------------------------------------------ main


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repo root (default: the checkout containing this script)",
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="seed violations into a temp tree and verify they are caught",
    )
    args = ap.parse_args()

    if args.self_test:
        return self_test()

    findings = run_checks(args.root)
    if findings:
        print("\n".join(findings))
        print(f"FAILED: {len(findings)} invariant violation(s)")
        return 1
    n_files = len(rust_files(args.root / "rust" / "src"))
    print(f"OK: {len(CHECKS)} invariants hold across {n_files} source files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
