#!/usr/bin/env bash
# c10k stress of the async serving plane (DESIGN.md §Serving-async):
# one `liquidsvm serve` process on an ephemeral loopback port, then the
# event-driven swarm client drives thousands of concurrent connections
# through both wire formats.  The swarm keeps strict per-request
# accounting and exits non-zero on ANY dropped reply, so this script's
# contract is simply: both runs finish, and both report failed=0.
#
# CI runs this as the serve-stress job after a release build; locally:
#   cargo build --release --manifest-path rust/Cargo.toml
#   bash scripts/serve_stress.sh [CONNS] [REQS_PER_CONN]

set -euo pipefail
cd "$(dirname "$0")/.."

BIN=rust/target/release/liquidsvm
[ -x "$BIN" ] || { echo "error: $BIN not built (cargo build --release)" >&2; exit 2; }

CONNS="${1:-10000}"
REQS="${2:-5}"

# each connection needs a client fd and a server fd, plus slack for
# the listener, wake pipes, logs, and the runtime
NEED=$((CONNS * 2 + 512))
ulimit -n "$NEED" 2>/dev/null || true
HAVE="$(ulimit -n)"
if [ "$HAVE" != "unlimited" ] && [ "$HAVE" -lt "$NEED" ]; then
  CONNS=$(( (HAVE - 512) / 2 ))
  [ "$CONNS" -ge 100 ] || { echo "error: open-file limit $HAVE too low even for a reduced sweep" >&2; exit 2; }
  echo "warning: open-file limit $HAVE < $NEED, reducing sweep to $CONNS connections" >&2
fi
echo "== sweep: $CONNS connections x $REQS requests (ulimit -n $(ulimit -n))"

WORK="$(mktemp -d)"
PIDS=()
cleanup() {
  for pid in "${PIDS[@]:-}"; do kill "$pid" 2>/dev/null || true; done
  rm -rf "$WORK"
}
trap cleanup EXIT

echo "== train + save the model under stress"
"$BIN" train --data banana --n 400 --seed 33 --folds 2 --scenario binary \
  --save "$WORK/stress.sol"

echo "== start the server (ephemeral port, 10k-conn admission headroom)"
"$BIN" serve --port 0 --models "stress=$WORK/stress.sol" \
  --max-batch 64 --workers 4 > "$WORK/serve.log" &
PIDS+=($!)
for _ in $(seq 1 100); do
  grep -q "serving on " "$WORK/serve.log" && break
  sleep 0.1
done
ADDR="$(sed -n 's/^serving on //p' "$WORK/serve.log" | head -n1)"
[ -n "$ADDR" ] || { echo "error: server did not report an address" >&2; cat "$WORK/serve.log" >&2; exit 1; }
echo "   serving on $ADDR"

TOTAL=$((CONNS * REQS))
run_leg() { # $1 = label, extra client flags follow
  local label="$1"; shift
  echo "== swarm leg: $label"
  "$BIN" client --addr "$ADDR" --model stress --data banana --n "$TOTAL" \
    --connections "$CONNS" --pipeline 4 --swarm "$@" | tee "$WORK/$label.log"
  # the swarm already hard-fails on dropped replies; belt-and-braces,
  # hold the printed accounting to zero failures too
  grep -q " failed=0 " "$WORK/$label.log" || { echo "error: $label leg reported failures" >&2; exit 1; }
}

run_leg text
run_leg binary --binary

echo "serve-stress OK: $CONNS conns x $REQS reqs, both wire formats, zero dropped replies"
