#!/usr/bin/env bash
# Documentation link check, run by CI's docs job:
#  1. every relative markdown link in README.md / DESIGN.md resolves
#     to a file or directory in the repo;
#  2. every in-source citation `DESIGN.md §<Section>` resolves to a
#     real `## <Section>` heading in DESIGN.md.
set -euo pipefail
cd "$(dirname "$0")/.."
fail=0

for f in README.md DESIGN.md; do
  [ -f "$f" ] || { echo "missing $f"; fail=1; continue; }
  # extract link targets: ](target) — skip absolute URLs and pure anchors
  while IFS= read -r target; do
    case "$target" in
      http://*|https://*|mailto:*|\#*) continue ;;
    esac
    target="${target%%#*}"            # strip in-page anchors
    [ -n "$target" ] || continue
    if [ ! -e "$target" ]; then
      echo "$f: broken link -> $target"
      fail=1
    fi
  done < <(grep -oE '\]\([^)]+\)' "$f" | sed 's/^](//; s/)$//')
done

if [ -f DESIGN.md ]; then
  while IFS= read -r sec; do
    if ! grep -qE "^## ${sec}\b" DESIGN.md; then
      echo "unresolved citation: DESIGN.md §${sec}"
      fail=1
    fi
  done < <(grep -rhoE 'DESIGN\.md §[A-Za-z][A-Za-z-]*' rust/src | sed 's/.*§//' | sort -u)
else
  echo "missing DESIGN.md"
  fail=1
fi

if [ "$fail" -ne 0 ]; then
  echo "doc check FAILED"
  exit 1
fi
echo "doc check OK"
