#!/usr/bin/env python3
"""Compare two bench snapshot sets (schema liquidsvm-bench-snapshot/v1).

Each set is a directory of BENCH_<name>.json files written by the
bench harness (rust/benches/harness.rs, schema documented in
DESIGN.md §Observability).  The diff is warn-only by design — missing
benches, new/renamed cases, seed baselines (``"seed": true``, the
structure-only files committed under rust/benches/snapshots/), and
environment mismatches all produce warnings, never failures — except
for one hard gate: a case whose throughput drops by more than the
threshold (default 2x) against a comparable baseline fails the run.

Usage:
    bench_diff.py BASELINE_DIR CURRENT_DIR [--fail-threshold X]

Exit status: 0 = ok (possibly with warnings), 1 = real regression,
2 = usage / unreadable input.
"""

import argparse
import glob
import json
import os
import sys

SCHEMA = "liquidsvm-bench-snapshot/v1"


def load_set(dirname):
    """Read every BENCH_*.json in `dirname`; skip unreadable files."""
    out = {}
    for path in sorted(glob.glob(os.path.join(dirname, "BENCH_*.json"))):
        try:
            with open(path, encoding="utf-8") as f:
                snap = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warn: skipping unreadable {path}: {e}")
            continue
        if snap.get("schema") != SCHEMA:
            print(f"warn: {path}: schema {snap.get('schema')!r} != {SCHEMA!r}, skipping")
            continue
        out[snap.get("bench", os.path.basename(path))] = snap
    return out


def env_comparable(base, cur):
    """Timings are only gate-worthy when profile and scale match."""
    be, ce = base.get("env", {}), cur.get("env", {})
    reasons = []
    for key in ("profile", "scale"):
        if be.get(key) != ce.get(key):
            reasons.append(f"{key} {be.get(key)!r} vs {ce.get(key)!r}")
    if be.get("cpus") != ce.get("cpus"):
        # different core count skews throughput but not catastrophically;
        # warn, still compare
        print(f"warn: cpu count differs ({be.get('cpus')} vs {ce.get('cpus')})")
    return reasons


def diff_bench(name, base, cur, threshold):
    """Compare one bench pair; return the number of hard regressions."""
    if base.get("seed"):
        print(f"note: {name}: baseline is a seed snapshot (no timings) — structure check only")
        base_names = {c.get("name") for c in base.get("cases", [])}
        for c in cur.get("cases", []):
            if base_names and c.get("name") not in base_names:
                print(f"note: {name}/{c.get('name')}: new case (not in seed)")
        return 0

    mismatch = env_comparable(base, cur)
    if mismatch:
        print(f"warn: {name}: env not comparable ({', '.join(mismatch)}) — warn-only")

    base_cases = {c.get("name"): c for c in base.get("cases", [])}
    cur_cases = {c.get("name"): c for c in cur.get("cases", [])}
    regressions = 0

    for cname in sorted(base_cases.keys() | cur_cases.keys()):
        b, c = base_cases.get(cname), cur_cases.get(cname)
        if b is None:
            print(f"note: {name}/{cname}: new case")
            continue
        if c is None:
            print(f"warn: {name}/{cname}: case disappeared")
            continue
        bt, ct = b.get("throughput", 0) or 0, c.get("throughput", 0) or 0
        if bt <= 0 or ct <= 0:
            print(f"note: {name}/{cname}: no throughput to compare")
            continue
        ratio = bt / ct
        unit = c.get("unit", "")
        line = f"{name}/{cname}: {bt:.3g} -> {ct:.3g} {unit} ({'-' if ratio > 1 else '+'}{abs(1 - 1 / ratio) * 100:.0f}%)"
        if ratio > threshold:
            if mismatch:
                print(f"warn: {line} — would fail, but env differs")
            else:
                print(f"REGRESSION: {line} (>{threshold}x slower)")
                regressions += 1
        elif ratio < 1 / threshold:
            print(f"note: {line} (faster)")
        else:
            print(f"ok: {line}")
    return regressions


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="directory with baseline BENCH_*.json")
    ap.add_argument("current", help="directory with current BENCH_*.json")
    ap.add_argument(
        "--fail-threshold",
        type=float,
        default=2.0,
        help="fail when throughput drops by more than this factor (default 2.0)",
    )
    args = ap.parse_args()

    for d in (args.baseline, args.current):
        if not os.path.isdir(d):
            print(f"error: not a directory: {d}")
            return 2
    base_set, cur_set = load_set(args.baseline), load_set(args.current)
    if not cur_set:
        print(f"warn: no snapshots found in {args.current} — nothing to compare")
        return 0

    regressions = 0
    for name in sorted(cur_set):
        if name not in base_set:
            print(f"note: {name}: no baseline snapshot — skipping")
            continue
        regressions += diff_bench(name, base_set[name], cur_set[name], args.fail_threshold)
    for name in sorted(set(base_set) - set(cur_set)):
        print(f"warn: {name}: baseline exists but no current snapshot")

    if regressions:
        print(f"bench diff FAILED: {regressions} regression(s) beyond {args.fail_threshold}x")
        return 1
    print("bench diff OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
