#!/usr/bin/env bash
# End-to-end smoke of the distributed train wire (DESIGN.md
# §Distributed-wire): start two real `liquidsvm worker` processes on
# ephemeral loopback ports, run the coordinator against them, and hold
# the result to the byte-identity contract — the assembled `.sol.d`
# bundle must equal a monolithic `train --save` bundle file for file,
# and both must predict identically.
#
# CI runs this as the dist-smoke job after a release build; locally:
#   cargo build --release --manifest-path rust/Cargo.toml
#   bash scripts/dist_smoke.sh

set -euo pipefail
cd "$(dirname "$0")/.."

BIN=rust/target/release/liquidsvm
[ -x "$BIN" ] || { echo "error: $BIN not built (cargo build --release)" >&2; exit 2; }

WORK="$(mktemp -d)"
PIDS=()
cleanup() {
  for pid in "${PIDS[@]:-}"; do kill "$pid" 2>/dev/null || true; done
  rm -rf "$WORK"
}
trap cleanup EXIT

# identical data/partition/CV flags for every run — the contract needs
# all three paths to see the same problem
FLAGS=(--data banana --n 500 --seed 21 --folds 2 --cells 1,100 --scenario binary)

start_worker() { # $1 = banner file, extra args follow
  local banner="$1"; shift
  "$BIN" worker --port 0 "$@" > "$banner" &
  PIDS+=($!)
  # the first stdout line is the parseable contract: `worker listening on ADDR`
  for _ in $(seq 1 100); do
    if grep -q "worker listening on " "$banner"; then break; fi
    sleep 0.1
  done
  sed -n 's/^worker listening on //p' "$banner" | head -n1
}

echo "== monolithic reference bundle"
"$BIN" train "${FLAGS[@]}" --save "$WORK/mono.sol.d"

echo "== starting 2 workers"
ADDR1="$(start_worker "$WORK/w1.log")"
ADDR2="$(start_worker "$WORK/w2.log")"
[ -n "$ADDR1" ] && [ -n "$ADDR2" ] || { echo "error: workers did not report an address" >&2; exit 1; }
echo "   workers at $ADDR1 and $ADDR2"

echo "== distributed train over the wire"
"$BIN" distributed "${FLAGS[@]}" \
  --workers "$ADDR1,$ADDR2" --save "$WORK/dist.sol.d" | tee "$WORK/dist.log"
grep -q "measured_wall=" "$WORK/dist.log" || { echo "error: no measured wall reported" >&2; exit 1; }
grep -q "redispatched=0" "$WORK/dist.log" || { echo "error: healthy run re-dispatched cells" >&2; exit 1; }

echo "== byte-identity: distributed bundle vs monolithic bundle"
diff -r "$WORK/mono.sol.d" "$WORK/dist.sol.d"

echo "== predictions agree"
"$BIN" predict --model "$WORK/mono.sol.d" --data banana --n 300 --seed 21 --out "$WORK/mono.pred"
"$BIN" predict --model "$WORK/dist.sol.d" --data banana --n 300 --seed 21 --out "$WORK/dist.pred"
cmp "$WORK/mono.pred" "$WORK/dist.pred"

echo "dist-smoke OK: bundle byte-identical, predictions identical"
