//! Quantile regression (`qtSVM`) — one of the "more involved estimation
//! problems" the paper's intro motivates: simultaneous estimation of
//! several conditional quantiles with the pinball-loss solver.
//!
//! The workload is a heteroscedastic 1-d regression problem whose true
//! quantile curves fan out with x; the example trains τ ∈ {5%, 25%,
//! 50%, 75%, 95%}, prints per-level pinball losses and empirical
//! coverage, and checks the quantile curves do not cross on average.
//!
//! Run: `cargo run --release --example quantile_regression`

use liquid_svm::data::synth;
use liquid_svm::metrics::Loss;
use liquid_svm::prelude::*;

fn main() -> anyhow::Result<()> {
    let taus = [0.05f32, 0.25, 0.5, 0.75, 0.95];
    let train = synth::sinc_hetero(800, 7);
    let test = synth::sinc_hetero(500, 8);

    let cfg = Config::default().display(1).folds(3);
    let model = qt_svm(&train, &taus, &cfg)?;
    let res = model.test(&test);

    println!("\nquantile regression on sinc-heteroscedastic (n=800)");
    println!("  train time {:.2}s", model.train_time.as_secs_f64());
    println!("  tau    pinball   coverage(y<=q)");
    for (t, &tau) in taus.iter().enumerate() {
        let scores = &res.task_scores[t];
        let pin = Loss::Pinball { tau }.mean(&test.y, scores);
        let cov = scores.iter().zip(&test.y).filter(|(q, y)| *y <= *q).count() as f32
            / test.y.len() as f32;
        println!("  {tau:.2}   {pin:.4}    {cov:.3}");
        // coverage should land near tau
        assert!((cov - tau).abs() < 0.15, "tau={tau}: coverage {cov} too far off");
    }

    // monotone ordering of the quantile curves (on average)
    for t in 1..taus.len() {
        let gap: f32 = res.task_scores[t]
            .iter()
            .zip(&res.task_scores[t - 1])
            .map(|(hi, lo)| hi - lo)
            .sum::<f32>()
            / test.y.len() as f32;
        assert!(gap >= -0.01, "quantile curves crossed: tau[{t}] below tau[{}]", t - 1);
    }
    println!("\nOK — curves ordered, coverage tracks tau");
    Ok(())
}
