//! Quickstart — the paper's Appendix A demo (`mc-svm.sh banana-mc 1 2`
//! / `mcSVM(Y ~ ., d$train, display=1, threads=2)`) in this port.
//!
//! Run: `cargo run --release --example quickstart`

use liquid_svm::data::synth;
use liquid_svm::prelude::*;

fn main() -> anyhow::Result<()> {
    // d <- liquidData('banana-mc')
    let d = synth::banana_mc(2000, 1000, 42);

    // model <- mcSVM(Y ~ ., d$train, display=1, threads=2)
    let cfg = Config::default().display(1).threads(2);
    let model = mc_svm(&d.train, &cfg)?;

    // result <- test(model, d$test)
    let result = model.test(&d.test);

    println!("\nbanana-mc multiclass (4 classes, OvA decomposition)");
    println!("  train samples : {}", d.train.len());
    println!("  tasks trained : {}", model.n_tasks);
    println!("  train time    : {:.2}s", model.train_time.as_secs_f64());
    println!("  test error    : {:.4}", result.error);
    for (cell, task, gamma, lambda) in model.selected_params().iter().take(4) {
        println!("  unit cell={cell} task={task}: gamma={gamma:.3} lambda={lambda:.2e}");
    }
    assert!(result.error < 0.2, "quickstart should reach <20% error");
    println!("\nOK");
    Ok(())
}
