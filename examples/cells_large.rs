//! Cell decomposition at scale (paper §2 "Managing Working Sets" +
//! Table 3): train on a covtype-like set large enough that a single
//! full-Gram SVM would be painful, using the recursive-partition cells
//! (voronoi=6) that make the cost linear in n.
//!
//! Also demonstrates the XLA backend: pass `--backend xla` (after
//! `make artifacts`) to route the Gram hot spot through the AOT
//! Pallas/PJRT artifacts instead of the CPU loops.
//!
//! Run: `cargo run --release --example cells_large [-- --backend xla]`

use liquid_svm::cells::CellStrategy;
use liquid_svm::coordinator::config::BackendChoice;
use liquid_svm::data::synth;
use liquid_svm::prelude::*;

fn main() -> anyhow::Result<()> {
    let backend = if std::env::args().any(|a| a == "xla") || std::env::args().any(|a| a == "--backend-xla")
        || std::env::args().collect::<Vec<_>>().windows(2).any(|w| w[0] == "--backend" && w[1] == "xla")
    {
        BackendChoice::Xla
    } else {
        BackendChoice::Blocked
    };

    let n = 20_000;
    let train = synth::by_name("covtype", n, 11).unwrap();
    let test = synth::by_name("covtype", 4000, 12).unwrap();

    println!("covtype-sim n={n} d={} backend={backend:?}", train.dim());

    let cfg = Config::default()
        .display(1)
        .folds(5)
        .voronoi(CellStrategy::RecursiveTree { max_size: 1000 })
        .backend(backend);
    let t0 = std::time::Instant::now();
    let model = svm_binary(&train, 0.5, &cfg)?;
    let train_time = t0.elapsed();
    let res = model.test(&test);

    println!("\n  cells        : {}", model.partition.n_cells());
    println!("  grid points  : {}", model.points_evaluated);
    println!("  train time   : {:.2}s", train_time.as_secs_f64());
    println!("  test time    : {:.2}s", res.test_time.as_secs_f64());
    println!("  test error   : {:.4}", res.error);
    println!(
        "  throughput   : {:.0} train samples/s, {:.0} predictions/s",
        n as f64 / train_time.as_secs_f64(),
        4000.0 / res.test_time.as_secs_f64().max(1e-9)
    );
    assert!(res.error < 0.25, "cells error {}", res.error);
    println!("\nOK");
    Ok(())
}
