//! Neyman-Pearson classification (`nplSVM`) — "classification with a
//! constraint on the false alarm rate" (paper §1): sweep weighted
//! machines, then select the one whose validation false-alarm rate
//! stays below α while maximizing detection.
//!
//! Run: `cargo run --release --example npl_classification`

use liquid_svm::coordinator::npl::{operating_points, select_npl_task};
use liquid_svm::data::synth;
use liquid_svm::metrics::Confusion;
use liquid_svm::prelude::*;

fn main() -> anyhow::Result<()> {
    let alpha = 0.10; // max false-alarm rate
    let train = synth::by_name("thyroid-ann", 1200, 3).unwrap();
    let val = synth::by_name("thyroid-ann", 600, 4).unwrap();
    let test = synth::by_name("thyroid-ann", 800, 5).unwrap();

    let cfg = Config::default().display(1).folds(3);
    let model = npl_svm(&train, alpha, &cfg)?;

    // operating points on held-out validation data
    let val_scores = model.decision_values(&val.x);
    let pts = operating_points(&val.y, &val_scores);
    println!("\nNPL sweep (alpha = {alpha}):");
    for (t, (fa, det)) in pts.iter().enumerate() {
        println!("  machine {t}: false-alarm {fa:.3}  detection {det:.3}");
    }
    let chosen = select_npl_task(&val.y, &val_scores, alpha);
    println!("  -> selected machine {chosen}");

    // evaluate the selected machine on the test set
    let test_scores = model.decision_values(&test.x);
    let c = Confusion::from_scores(&test.y, &test_scores[chosen]);
    println!(
        "\ntest: false-alarm {:.3} (bound {alpha}), detection {:.3}, error {:.3}",
        c.false_alarm_rate(),
        c.detection_rate(),
        c.error()
    );
    assert!(
        c.false_alarm_rate() <= alpha * 2.0 + 0.05,
        "false alarm rate blew past the constraint"
    );
    println!("OK");
    Ok(())
}
