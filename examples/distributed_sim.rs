//! END-TO-END DRIVER — the full system on a realistic workload
//! (paper §4 Table 4 / Appendix B.3, scaled to this machine).
//!
//! Reproduces the whole distributed pipeline on a covtype-like
//! workload: driver samples the data and places coarse Voronoi centers
//! → shuffle assigns every coarse cell to a worker → each worker runs
//! the single-node engine (fine recursive cells, integrated 5-fold CV
//! on the default grid, warm starts, kernel reuse) → test points route
//! coarse cell → fine cell → fold-averaged SVM.  Reports the paper's
//! Table-4 quantities: distributed vs single-node (modelled) time,
//! speedup, and test error.  Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example distributed_sim`

use liquid_svm::data::synth;
use liquid_svm::distributed::{train_distributed, ClusterSpec};
use liquid_svm::prelude::*;
use liquid_svm::tasks::TaskSpec;

fn main() -> anyhow::Result<()> {
    let n = 40_000;
    let train = synth::by_name("covtype", n, 21).unwrap();
    let test = synth::by_name("covtype", 6000, 22).unwrap();

    let cluster = ClusterSpec {
        workers: 14,          // the paper's worker count
        coarse_size: 4000,    // paper: 20 000 (scaled to this machine)
        fine_size: 1000,      // paper: 2000
        driver_sample: 6000,
    };
    let cfg = Config::default().display(1).folds(5);

    println!(
        "distributed covtype-sim: n={n} d={} workers={} coarse={} fine={}",
        train.dim(),
        cluster.workers,
        cluster.coarse_size,
        cluster.fine_size
    );

    let t0 = std::time::Instant::now();
    let model = train_distributed(&train, &TaskSpec::Binary { w: 0.5 }, &cfg, &cluster)?;
    let wall = t0.elapsed();
    let err = model.test_error(&test);

    let s = &model.stats;
    println!("\n  coarse cells      : {}", s.n_coarse_cells);
    println!("  driver phase      : {:.2}s", s.driver_time.as_secs_f64());
    println!("  shuffle phase     : {:.2}s", s.shuffle_time.as_secs_f64());
    println!("  wall time (1 core): {:.2}s", wall.as_secs_f64());
    println!(
        "  distributed time  : {:.2}s   (modelled critical path over {} workers)",
        s.distributed_time.as_secs_f64(),
        s.workers
    );
    println!(
        "  single-node time  : {:.2}s   (modelled sequential + CLI overhead)",
        s.single_node_time.as_secs_f64()
    );
    println!("  speedup           : {:.1}x", s.speedup());
    println!("  test error        : {:.4}", err);
    println!(
        "  throughput        : {:.0} samples/s end-to-end",
        n as f64 / wall.as_secs_f64()
    );

    assert!(err < 0.25, "distributed error {err}");
    assert!(s.speedup() > 2.0, "speedup {}", s.speedup());
    println!("\nOK — full three-layer stack exercised end to end");
    Ok(())
}
